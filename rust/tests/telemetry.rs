//! Property tests for the deploy telemetry spine: the log₂ histogram
//! places powers of two exactly on their bucket's upper bound, snapshot
//! merging is associative/commutative and equivalent to recording the
//! union, and `quantile_bounds` brackets the *exact* nearest-rank
//! percentile computed by the `percentiles_ms` oracle on the same
//! samples. Trace span math is pinned with a [`ManualClock`] so every
//! asserted number is deterministic.
//!
//! The windowed layer (`telemetry::window`) gets the same treatment
//! under explicit caller-supplied time: a seeded stream of (time, value)
//! samples spanning several windows must snapshot bit-identically to a
//! cumulative histogram fed only the retained samples (the
//! merge-consistency property), windowed `quantile_bounds` must bracket
//! the exact oracle over those retained samples, and the rotation edge
//! cases — jumps past the whole window, sub-epoch repeated reads — are
//! pinned explicitly.

use std::sync::Arc;
use std::time::Duration;

use cgmq::bench_harness::percentiles_ms;
use cgmq::deploy::telemetry::{bucket_upper_us, BUCKETS};
use cgmq::deploy::{
    Histogram, HistogramSnapshot, ManualClock, ServerTelemetry, SpanRecorder, Stage,
    WindowedCounter, WindowedHistogram, WINDOW_SLOTS,
};

/// Deterministic xorshift64* so the sample sets are seeded, not random.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
}

/// Seeded latency samples in µs spanning several orders of magnitude
/// (sub-µs ties, mid-range bulk, a heavy tail) — the shape a real serve
/// latency distribution has.
fn seeded_samples(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng(seed | 1);
    (0..n)
        .map(|i| {
            let r = rng.next();
            match i % 4 {
                0 => r % 2,                  // 0..=1 µs: the shared bucket 0
                1 => 2 + r % 1_000,          // O(ms) bulk
                2 => 1_000 + r % 100_000,    // slow requests
                _ => 100_000 + r % 5_000_000, // tail, up to seconds
            }
        })
        .collect()
}

fn recorded(samples: &[u64]) -> HistogramSnapshot {
    let h = Histogram::default();
    for &us in samples {
        h.record(Duration::from_micros(us));
    }
    h.snapshot()
}

#[test]
fn powers_of_two_land_exactly_on_their_bucket_upper_bound() {
    // One sample at every bucket's upper bound: exactly one count per
    // bucket, no spill in either direction.
    let h = Histogram::default();
    for b in 0..BUCKETS {
        h.record(Duration::from_micros(bucket_upper_us(b)));
    }
    let snap = h.snapshot();
    assert_eq!(snap.counts, [1u64; BUCKETS], "upper bounds must be inclusive");
    assert_eq!(snap.count, BUCKETS as u64);

    // One past each upper bound spills into the next bucket (the top
    // bucket clamps).
    let h = Histogram::default();
    for b in 0..BUCKETS - 1 {
        h.record(Duration::from_micros(bucket_upper_us(b) + 1));
    }
    let snap = h.snapshot();
    assert_eq!(snap.counts[0], 0, "upper_bound+1 must not stay in its bucket");
    for b in 1..BUCKETS {
        assert_eq!(snap.counts[b], 1, "2^{}+1 must land in bucket {b}", b - 1);
    }
}

#[test]
fn merge_is_associative_commutative_and_matches_recording_the_union() {
    let s1 = seeded_samples(11, 257);
    let s2 = seeded_samples(23, 128);
    let s3 = seeded_samples(47, 63);
    let (a, b, c) = (recorded(&s1), recorded(&s2), recorded(&s3));

    // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
    let mut left = a;
    left.merge(&b);
    left.merge(&c);
    let mut bc = b;
    bc.merge(&c);
    let mut right = a;
    right.merge(&bc);
    assert_eq!(left, right, "merge must be associative");

    // a ⊕ b == b ⊕ a
    let mut ab = a;
    ab.merge(&b);
    let mut ba = b;
    ba.merge(&a);
    assert_eq!(ab, ba, "merge must be commutative");

    // Merging shard snapshots == recording every sample into one
    // histogram (how per-stage totals are assembled across shards).
    let mut union: Vec<u64> = s1.clone();
    union.extend_from_slice(&s2);
    union.extend_from_slice(&s3);
    assert_eq!(left, recorded(&union), "merge must equal the union recording");
    assert_eq!(left.count, (s1.len() + s2.len() + s3.len()) as u64);
}

#[test]
fn quantile_bounds_bracket_the_exact_percentiles_ms_oracle() {
    for seed in [3u64, 19, 101, 977] {
        for n in [1usize, 2, 17, 500] {
            let samples = seeded_samples(seed, n);
            let snap = recorded(&samples);

            // The exact oracle: same samples, seconds in, ms out.
            let mut durs: Vec<f64> = samples.iter().map(|&us| us as f64 * 1e-6).collect();
            let (p50, p90, p99) = percentiles_ms(&mut durs);

            for (q, p_ms) in [(0.50, p50), (0.90, p90), (0.99, p99)] {
                let exact_us = (p_ms * 1e3).round() as u64;
                let (lo, hi) = snap
                    .quantile_bounds(q)
                    .expect("non-empty histogram has quantile bounds");
                assert!(
                    lo <= exact_us && exact_us <= hi,
                    "seed {seed} n {n} q {q}: exact {exact_us}µs outside [{lo}, {hi}]"
                );
                assert!(hi <= snap.max_us, "upper bound must not exceed the max sample");
            }

            // q = 1.0 picks the bucket holding the max, and the max caps
            // the bracket — the estimate degrades gracefully to exact.
            let (lo, hi) = snap.quantile_bounds(1.0).unwrap();
            assert_eq!(hi, snap.max_us);
            assert!(lo <= snap.max_us);
        }
    }

    // Empty histograms answer None, not a fake zero percentile.
    assert_eq!(HistogramSnapshot::default().quantile_bounds(0.5), None);
    assert_eq!(HistogramSnapshot::default().mean_us(), 0.0);
}

#[test]
fn manual_clock_traces_are_deterministic_end_to_end() {
    let clock = Arc::new(ManualClock::default());
    let tel = ServerTelemetry::new(&["m".to_string()], clock.clone(), 2);

    // Three requests with known span patterns; the ring keeps the last 2.
    for (i, (parse_us, admit_us, status)) in
        [(100u64, 7u64, 200u16), (250, 3, 429), (40, 9, 200)].into_iter().enumerate()
    {
        let id = tel.next_request_id();
        assert_eq!(id, i as u64 + 1, "request ids are a 1-based sequence");
        let mut rec = SpanRecorder::start(tel.clock());
        clock.advance(Duration::from_micros(parse_us));
        rec.mark(Stage::Parse);
        clock.advance(Duration::from_micros(admit_us));
        rec.mark(Stage::Admit);
        if status == 200 {
            rec.set(Stage::Compute, Duration::from_micros(500));
        }
        tel.record(rec, "m", id, status);
    }

    let snap = tel.snapshot();
    let m = &snap.models["m"];
    assert_eq!(m.status_count(200), 2);
    assert_eq!(m.status_count(429), 1);
    assert_eq!(m.total(), 3);

    // Stage histograms saw exactly the recorded spans: sums and counts
    // are exact integers under the manual clock.
    let parse = &m.stages[Stage::Parse as usize];
    assert_eq!((parse.count, parse.sum_us, parse.max_us), (3, 390, 250));
    let admit = &m.stages[Stage::Admit as usize];
    assert_eq!((admit.count, admit.sum_us, admit.max_us), (3, 19, 9));
    // The shed request never touched compute: only the two 200s recorded.
    let compute = &m.stages[Stage::Compute as usize];
    assert_eq!((compute.count, compute.sum_us), (2, 1000));
    let accept = &m.stages[Stage::Accept as usize];
    assert_eq!(accept.count, 0, "untouched stages must not record zeros");

    // Ring cap 2: the oldest trace fell off; spans survive verbatim.
    let traces = tel.recent_traces();
    assert_eq!(traces.len(), 2);
    assert_eq!(traces[0].request_id, 2);
    assert_eq!(traces[0].status, 429);
    assert_eq!(traces[1].request_id, 3);
    assert_eq!(traces[1].spans[Stage::Parse as usize], 40);
    assert_eq!(traces[1].total_us(), 40 + 9 + 500);
    // started_us is the manual clock's reading when the span opened:
    // request 3 started after the first two requests' 360µs of advances.
    assert_eq!(traces[1].started_us, 360);
}

/// 1 ms epochs for the windowed tests, so the seeded times stay small
/// and the window spans 10 ms.
const EPOCH: Duration = Duration::from_micros(1_000);

const EPOCH_US: u64 = 1_000;

/// Seeded (time, value) stream spanning 2.5 windows of epochs, times
/// sorted non-decreasing (wall clocks are monotonic, and lazy rotation
/// assumes it). Values reuse the multi-order-of-magnitude shape of
/// [`seeded_samples`].
fn seeded_windowed_samples(seed: u64, n: usize) -> Vec<(Duration, u64)> {
    let mut rng = Rng(seed | 1);
    let span_epochs = WINDOW_SLOTS as u64 * 5 / 2;
    let mut out: Vec<(u64, u64)> = (0..n)
        .map(|i| {
            let epoch = rng.next() % span_epochs;
            let offset = rng.next() % EPOCH_US;
            let r = rng.next();
            let v = match i % 4 {
                0 => r % 2,
                1 => 2 + r % 1_000,
                2 => 1_000 + r % 100_000,
                _ => 100_000 + r % 5_000_000,
            };
            (epoch * EPOCH_US + offset, v)
        })
        .collect();
    out.sort_by_key(|&(t, _)| t);
    out.into_iter().map(|(t, v)| (Duration::from_micros(t), v)).collect()
}

#[test]
fn windowed_snapshot_equals_recording_only_the_retained_samples() {
    for seed in [5u64, 29, 463, 1021] {
        for n in [1usize, 2, 16, 300] {
            let samples = seeded_windowed_samples(seed, n);
            let h = WindowedHistogram::new(EPOCH);
            let c = WindowedCounter::new(EPOCH);
            for &(t, v) in &samples {
                h.record(t, v);
                c.record(t, 1);
            }
            // Read at the last sample's time: the oracle retained set is
            // every sample whose epoch is inside the trailing window.
            // (A sample whose slot was reclaimed by a later epoch is
            // always outside the window by then, so the filter and the
            // ring agree exactly under sequenced time.)
            let now = samples.last().expect("n >= 1").0;
            let cur = now.as_micros() as u64 / EPOCH_US;
            let retained: Vec<u64> = samples
                .iter()
                .filter(|(t, _)| cur - t.as_micros() as u64 / EPOCH_US < WINDOW_SLOTS as u64)
                .map(|&(_, v)| v)
                .collect();
            assert!(!retained.is_empty(), "the sample at `now` is always retained");
            assert_eq!(c.total(now), retained.len() as u64, "seed {seed} n {n}: counter");

            // Merge-consistency: the in-window merge must be bit-identical
            // to a cumulative histogram fed only the retained samples.
            let snap = h.snapshot(now);
            assert_eq!(snap, recorded(&retained), "seed {seed} n {n}: histogram");

            // And the windowed quantile bounds bracket the exact
            // nearest-rank oracle over those retained samples.
            let mut durs: Vec<f64> = retained.iter().map(|&us| us as f64 * 1e-6).collect();
            let (p50, p90, p99) = percentiles_ms(&mut durs);
            for (q, p_ms) in [(0.50, p50), (0.90, p90), (0.99, p99)] {
                let exact_us = (p_ms * 1e3).round() as u64;
                let (lo, hi) = snap.quantile_bounds(q).expect("retained set is non-empty");
                assert!(
                    lo <= exact_us && exact_us <= hi,
                    "seed {seed} n {n} q {q}: exact {exact_us}µs outside [{lo}, {hi}]"
                );
            }
        }
    }
}

#[test]
fn sub_epoch_reads_never_rotate_and_full_window_jumps_expire_everything() {
    let c = WindowedCounter::new(EPOCH);
    let h = WindowedHistogram::new(EPOCH);
    c.record(Duration::from_micros(250), 3);
    h.record(Duration::from_micros(250), 40);

    // Repeated reads anywhere inside the same epoch see the same state:
    // reads never claim or reset a slot, no matter how often they run.
    for t_us in [0u64, 250, 400, 999, 999, 999] {
        let t = Duration::from_micros(t_us);
        assert_eq!(c.total(t), 3);
        assert_eq!(h.snapshot(t).count, 1);
    }

    // Further records in the same epoch accumulate — a slot resets only
    // when a *new epoch* claims it, never from a same-epoch record.
    c.record(Duration::from_micros(700), 2);
    h.record(Duration::from_micros(700), 41);
    assert_eq!(c.total(Duration::from_micros(999)), 5);
    assert_eq!(h.snapshot(Duration::from_micros(999)).count, 2);

    // A jump farther than the whole window expires every slot at once —
    // purely on the reader side, without touching the ring.
    let far = EPOCH * (3 * WINDOW_SLOTS as u32);
    assert_eq!(c.total(far), 0);
    assert_eq!(h.snapshot(far), HistogramSnapshot::default());
    assert_eq!(h.snapshot(far).quantile_bounds(0.5), None, "empty window has no quantiles");

    // The expired slots are still reclaimable: the next record at the
    // far epoch starts from a clean slot, not the stale counts.
    c.record(far, 1);
    h.record(far, 7);
    assert_eq!(c.total(far), 1);
    let reborn = h.snapshot(far);
    assert_eq!((reborn.count, reborn.sum_us, reborn.max_us), (1, 7, 7));
}
