//! Runtime integration: load real artifacts through PJRT and check that the
//! compiled graphs agree with the Rust-side mirrors of the same math.

mod common;

use cgmq::gates::{GateSet, Granularity};
use cgmq::model::mlp;
use cgmq::quant::gate_for_bits;
use cgmq::runtime::{Arg, ArtifactSet};
use cgmq::tensor::{Tensor, TensorI32};
use cgmq::util::rng::SplitMix64;

fn setup() -> Option<(ArtifactSet, cgmq::model::ArchSpec)> {
    let dir = common::artifacts_dir()?;
    let mut set = ArtifactSet::open(&dir).unwrap();
    let arch = mlp();
    set.verify_arch(&arch).unwrap();
    for kind in ["qat_step", "eval", "eval_float", "calibrate"] {
        set.load(&format!("mlp_{kind}")).unwrap();
    }
    Some((set, arch))
}

fn eval_args<'a>(
    params: &'a [Tensor],
    bw: &'a Tensor,
    ba: &'a Tensor,
    gw: &'a [Tensor],
    ga: &'a [Tensor],
    x: &'a Tensor,
) -> Vec<Arg<'a>> {
    let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
    args.push(Arg::F32(bw));
    args.push(Arg::F32(ba));
    args.extend(gw.iter().map(Arg::F32));
    args.extend(ga.iter().map(Arg::F32));
    args.push(Arg::F32(x));
    args
}

#[test]
fn verify_arch_catches_drift() {
    let Some((set, _)) = setup() else { return };
    let mut wrong = mlp();
    wrong.layers[0].w_shape = vec![784, 100];
    assert!(set.verify_arch(&wrong).is_err());
}

#[test]
fn eval_at_32bit_matches_float_eval() {
    // With generous ranges and 32-bit gates the only difference between the
    // quantized and float graphs is the 8-bit input quantization.
    let Some((set, arch)) = setup() else { return };
    let params = arch.init_params(3);
    let n = arch.eval_batch;
    let mut rng = SplitMix64::new(5);
    let xdata: Vec<f32> = (0..n * 784).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let x = Tensor::new(vec![n, 784], xdata).unwrap();

    let float_out = {
        let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
        args.push(Arg::F32(&x));
        set.get("mlp_eval_float").unwrap().run(&args).unwrap()
    };

    let bw = Tensor::new(vec![3], (0..3).map(|i| params[2 * i].abs_max() * 4.0).collect())
        .unwrap();
    let ba = Tensor::full(&[2], 100.0);
    let gates = GateSet::new(&arch, Granularity::Individual);
    let gw = gates.materialize_all_w(&arch);
    let ga = gates.materialize_all_a(&arch);
    let quant_out = set
        .get("mlp_eval")
        .unwrap()
        .run(&eval_args(&params, &bw, &ba, &gw, &ga, &x))
        .unwrap();

    assert_eq!(quant_out[0].shape(), &[n, 10]);
    let max_diff: f32 = float_out[0]
        .data()
        .iter()
        .zip(quant_out[0].data())
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(max_diff < 0.2, "32-bit quantized eval drifted {max_diff} from float");
    // ... and the predictions agree almost everywhere.
    let pf = float_out[0].argmax_rows().unwrap();
    let pq = quant_out[0].argmax_rows().unwrap();
    let agree = pf.iter().zip(&pq).filter(|(a, b)| a == b).count();
    assert!(agree >= n - 4, "only {agree}/{n} predictions agree");
}

#[test]
fn lower_gates_degrade_logits_monotonically() {
    let Some((set, arch)) = setup() else { return };
    let params = arch.init_params(3);
    let n = arch.eval_batch;
    let mut rng = SplitMix64::new(6);
    let xdata: Vec<f32> = (0..n * 784).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
    let x = Tensor::new(vec![n, 784], xdata).unwrap();
    let bw = Tensor::new(vec![3], (0..3).map(|i| params[2 * i].abs_max()).collect()).unwrap();
    let ba = Tensor::full(&[2], 8.0);

    let logits_at = |bits: u32| {
        let mut gates = GateSet::new(&arch, Granularity::Individual);
        for t in gates.gates_w.iter_mut().chain(gates.gates_a.iter_mut()) {
            *t = Tensor::full(&t.shape().to_vec(), gate_for_bits(bits));
        }
        let gw = gates.materialize_all_w(&arch);
        let ga = gates.materialize_all_a(&arch);
        set.get("mlp_eval").unwrap().run(&eval_args(&params, &bw, &ba, &gw, &ga, &x)).unwrap()
            [0]
        .clone()
    };

    let l32 = logits_at(32);
    let mut last = 0.0f64;
    for bits in [16u32, 8, 4, 2] {
        let lb = logits_at(bits);
        let mse: f64 = l32
            .data()
            .iter()
            .zip(lb.data())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / lb.len() as f64;
        assert!(
            mse >= last - 1e-9,
            "distortion not monotone: {bits} bit mse {mse} < previous {last}"
        );
        last = mse;
    }
    assert!(last > 1e-4, "2-bit quantization should visibly distort logits");
}

#[test]
fn qat_step_gradients_descend_loss() {
    let Some((set, arch)) = setup() else { return };
    let mut params = arch.init_params(7);
    let n = arch.train_batch;
    let data = cgmq::data::Dataset::synth(1, n);
    let x = Tensor::new(vec![n, 784], data.images.clone()).unwrap();
    let y = TensorI32::new(vec![n], data.labels.clone()).unwrap();
    let bw = Tensor::new(vec![3], (0..3).map(|i| params[2 * i].abs_max()).collect()).unwrap();
    let ba = Tensor::full(&[2], 6.0);
    let gates = GateSet::new(&arch, Granularity::Individual); // 32 bit
    let gw = gates.materialize_all_w(&arch);
    let ga = gates.materialize_all_a(&arch);

    let mut losses = Vec::new();
    for _ in 0..12 {
        let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
        args.push(Arg::F32(&bw));
        args.push(Arg::F32(&ba));
        args.extend(gw.iter().map(Arg::F32));
        args.extend(ga.iter().map(Arg::F32));
        args.push(Arg::F32(&x));
        args.push(Arg::I32(&y));
        let out = set.get("mlp_qat_step").unwrap().run(&args).unwrap();
        losses.push(out[0].item().unwrap());
        for (p, g) in params.iter_mut().zip(&out[1..7]) {
            p.zip_inplace(g, |p, g| p - 0.05 * g).unwrap();
        }
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "loss did not descend: {losses:?}"
    );
}

#[test]
fn shape_validation_rejects_bad_args() {
    let Some((set, arch)) = setup() else { return };
    let params = arch.init_params(0);
    let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
    let bad_x = Tensor::zeros(&[7, 784]); // wrong batch
    args.push(Arg::F32(&bad_x));
    let err = set.get("mlp_eval_float").unwrap().run(&args).unwrap_err().to_string();
    assert!(err.contains("shape"), "{err}");
    // arity mismatch
    let args2: Vec<Arg> = params.iter().map(Arg::F32).collect();
    assert!(set.get("mlp_eval_float").unwrap().run(&args2).is_err());
}

#[test]
fn calibrate_reports_positive_ranges() {
    let Some((set, arch)) = setup() else { return };
    let params = arch.init_params(11);
    let n = arch.train_batch;
    let data = cgmq::data::Dataset::synth(2, n);
    let x = Tensor::new(vec![n, 784], data.images).unwrap();
    let mut args: Vec<Arg> = params.iter().map(Arg::F32).collect();
    args.push(Arg::F32(&x));
    let out = set.get("mlp_calibrate").unwrap().run(&args).unwrap();
    let w_maxes = &out[0];
    let act_maxes = &out[1];
    assert_eq!(w_maxes.shape(), &[3]);
    assert_eq!(act_maxes.shape(), &[2]);
    for (li, &wm) in w_maxes.data().iter().enumerate() {
        let expect = params[2 * li].abs_max();
        assert!((wm - expect).abs() < 1e-5, "layer {li}: {wm} vs host {expect}");
    }
    assert!(act_maxes.data().iter().all(|&v| v > 0.0));
}
