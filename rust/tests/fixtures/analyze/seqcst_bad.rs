// Fixture: atomic-seqcst positive case — SeqCst inside a named hot
// function. The `ordering:` marker is present so only the SeqCst rule
// fires, isolating it from atomic-ordering.
use std::sync::atomic::{AtomicUsize, Ordering};

pub fn admit(depth: &AtomicUsize) -> usize {
    // ordering: seqcst — because it was the default
    depth.load(Ordering::SeqCst)
}
