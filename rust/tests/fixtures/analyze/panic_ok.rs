// Fixture: panic-hygiene negative case — typed fallbacks, an allowlisted
// site, a panic token inside a string literal, and test-gated code.
pub fn connection_loop(x: Option<u32>) -> u32 {
    match x {
        Some(v) => v,
        None => 0,
    }
}

pub fn load_time(x: Option<u32>) -> u32 {
    // analyze-allow: panic-hygiene validated before serving starts
    x.expect("validated")
}

pub fn message() -> &'static str {
    "string contents never trip the rule: panic!(), .unwrap()"
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
