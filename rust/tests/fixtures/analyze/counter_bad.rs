// Fixture: counter-choke positive case — a stats counter mutated outside
// its choke-point functions (`outstanding` belongs to submit /
// await_completion, not sweep). The ordering marker isolates the rule.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn sweep(outstanding: &AtomicU64) {
    // ordering: relaxed — counter only.
    outstanding.fetch_add(1, Ordering::Relaxed);
}
