// Fixture: a minimal Status::code mirror for the taxonomy-sync rule.
pub enum Status {
    Ok,
    BadRequest,
    TooManyRequests,
}

impl Status {
    pub fn code(self) -> u16 {
        match self {
            Status::Ok => 200,
            Status::BadRequest => 400,
            Status::TooManyRequests => 429,
        }
    }
}
