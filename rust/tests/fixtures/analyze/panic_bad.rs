// Fixture: panic-hygiene positive case — an unwrap in a deploy hot path.
pub fn connection_loop(x: Option<u32>) -> u32 {
    x.unwrap()
}
