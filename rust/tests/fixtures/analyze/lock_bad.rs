// Fixture: lock-scope positive cases — a blocking call under a live
// guard, and a second lock acquisition under a live guard.
use std::sync::mpsc::Receiver;
use std::sync::Mutex;

pub fn pump_loop(m: &Mutex<u32>, rx: &Receiver<u32>) -> u32 {
    let guard = m.lock().unwrap_or_else(|e| e.into_inner());
    let v = rx.recv().unwrap_or(0);
    *guard + v
}

pub fn sweep(a: &Mutex<u32>, b: &Mutex<u32>) -> u32 {
    let first = a.lock().unwrap_or_else(|e| e.into_inner());
    let second = b.lock().unwrap_or_else(|e| e.into_inner());
    *first + *second
}
