// Fixture: bad-allow positive cases — an annotation naming an unknown
// rule (typo) and one with no reason. Neither suppresses anything real;
// both must be reported so a typo cannot silently disable a rule.
pub fn admit(x: Option<u32>) -> u32 {
    // analyze-allow: panick-hygiene typo in the rule id
    // analyze-allow: panic-hygiene
    x.unwrap_or(0)
}
