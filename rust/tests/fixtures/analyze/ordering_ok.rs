// Fixture: atomic-ordering negative case — same-line justification, a
// justification directly above, and one at the top of a multi-line
// comment run.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn count(c: &AtomicU64) -> u64 {
    c.load(Ordering::Relaxed) // ordering: relaxed — display-only counter
}

pub fn count_above(c: &AtomicU64) -> u64 {
    // ordering: relaxed — no synchronization edge rides on this value.
    c.load(Ordering::Relaxed)
}

pub fn count_run(c: &AtomicU64) -> u64 {
    // ordering: relaxed — staleness is tolerated by the caller, which
    // treats the value as a hint and re-checks under the mutex; this
    // comment run spans several lines on purpose.
    c.load(Ordering::Relaxed)
}
