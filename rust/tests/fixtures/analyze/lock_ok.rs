// Fixture: lock-scope negative cases — the guard is dropped before the
// blocking call, a multi-line guard scope is closed by its block before
// the blocking call, and a documented double-lock is allowlisted.
use std::sync::mpsc::Receiver;
use std::sync::Mutex;

pub fn pump_loop(m: &Mutex<u32>, rx: &Receiver<u32>) -> u32 {
    let guard = m.lock().unwrap_or_else(|e| e.into_inner());
    let held = *guard;
    drop(guard);
    held + rx.recv().unwrap_or(0)
}

pub fn accept_loop(m: &Mutex<u32>) -> u32 {
    let mut total = 0;
    {
        let guard = m.lock().unwrap_or_else(|e| e.into_inner());
        total += *guard;
    }
    std::thread::sleep(std::time::Duration::from_millis(1));
    total
}

pub fn sweep(a: &Mutex<u32>, b: &Mutex<u32>) -> u32 {
    let first = a.lock().unwrap_or_else(|e| e.into_inner());
    // analyze-allow: lock-scope documented acquisition order a before b
    let second = b.lock().unwrap_or_else(|e| e.into_inner());
    *first + *second
}
