// Fixture: atomic-seqcst negative case — SeqCst in a cold function,
// Relaxed in a hot one, and an allowlisted load-bearing fence in a hot
// one.
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

pub fn stop(flag: &AtomicBool) {
    // ordering: seqcst — cold control-plane flag; no cost.
    flag.store(true, Ordering::SeqCst);
}

pub fn admit(depth: &AtomicUsize) -> usize {
    // ordering: relaxed — staleness sheds early at worst.
    depth.load(Ordering::Relaxed)
}

pub fn worker_loop(flag: &AtomicBool) -> bool {
    // ordering: seqcst — pairs with the store in stop() across threads.
    // analyze-allow: atomic-seqcst the full fence is load-bearing here
    flag.load(Ordering::SeqCst)
}
