//! Fixture: metric-name definitions as `metrics-name-sync` sees them.

pub const M_CONNECTIONS: &str = "cgmq_connections_total";
pub const M_REQUESTS: &str = "cgmq_requests_total";
// Prose naming a retired metric must not keep it alive: cgmq_retired_total
pub const M_STAGE_SECONDS: &str = "cgmq_stage_duration_seconds";
