// Fixture: atomic-ordering positive case — an Ordering:: use with no
// `ordering:` justification anywhere near it.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn count(c: &AtomicU64) -> u64 {
    c.load(Ordering::Relaxed)
}
