// Fixture: counter-choke negative case — every counter mutation sits in
// one of its named choke-point functions.
use std::sync::atomic::{AtomicU64, Ordering};

pub fn submit(outstanding: &AtomicU64) {
    // ordering: relaxed — counter only.
    outstanding.fetch_add(1, Ordering::Relaxed);
}

pub fn await_completion(outstanding: &AtomicU64, served: &AtomicU64) {
    // ordering: relaxed — counter only.
    outstanding.fetch_sub(1, Ordering::Relaxed);
    // ordering: relaxed — counter only.
    served.fetch_add(1, Ordering::Relaxed);
}
