//! Edge-deployment walkthrough: train under a device budget, export the
//! packed `.cgmqm` artifact, and *run* it — the full train → export-packed
//! → infer → serve loop, ending with the sharded multi-worker pool and a
//! two-tier model router that hot-swaps a variant mid-traffic.
//!
//!     cargo run --release --example edge_deployment
//!
//! This is the workflow the paper's introduction motivates: a practitioner
//! has a device with a hard compute budget (here: 1.4% of fp32 bit-ops),
//! runs the CGMQ pipeline once, and gets a mixed-precision model that
//! provably fits — then actually ships it: the best snapshot is bit-packed
//! into a `.cgmqm` artifact, loaded by the deploy engine, validated
//! bit-for-bit against the host fake-quant forward, served through the
//! request batcher, and finally exposed over a real HTTP/1.1 network
//! front (section 7) whose responses carry the same bits. (Training
//! executes compiled artifacts, so this example needs a `pjrt` build plus
//! `make artifacts`; everything after the `run()` call is pure host code.)

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use cgmq::config::Config;
use cgmq::deploy::{
    BatchConfig, DecodeMode, Engine, PackedModel, PoolConfig, RequestBatcher, Router, Submission,
    WorkerPool,
};
use cgmq::gates::{GateSet, Granularity};
use cgmq::quant::gate_for_bits;
use cgmq::session::{BestSnapshotSaver, SessionBuilder};

fn main() -> anyhow::Result<()> {
    let cfg = Config {
        arch: "mlp".into(),
        train_size: 2_000,
        test_size: 512,
        pretrain_epochs: 3,
        range_epochs: 1,
        cgmq_epochs: 10,
        granularity: cgmq::gates::Granularity::Individual,
        bound_rbop_percent: 1.40,
        gate_lr_scale: 10.0,
        out_dir: "runs/edge_deployment".into(),
        ..Config::default()
    };

    println!("device budget: {:.2}% of fp32 bit-operations\n", cfg.bound_rbop_percent);
    let out_dir = cfg.out_dir.clone();
    let ckpt = Path::new(&out_dir).join("deploy.ckpt");
    std::fs::create_dir_all(&out_dir)?;
    let cfg_export = cfg.clone();

    // ---- 1. Train under the constraint --------------------------------
    let mut session = SessionBuilder::new(cfg)
        .paper_pipeline()
        .observer(BestSnapshotSaver::new(&ckpt))
        .build()?;
    session.run()?;
    let result = session.result()?;
    let model = session.final_model()?;
    println!(
        "accuracy: {:.2}% (float was {:.2}%)",
        100.0 * result.quant_acc,
        100.0 * result.float_acc
    );
    println!(
        "RBOP: {:.3}% <= bound {:.2}%  [guaranteed]",
        result.rbop_percent, result.bound_rbop_percent
    );

    // ---- 2. Export: memory report + the packed artifact ----------------
    let report = cgmq::baselines::export_report(&cfg_export, &ckpt)?;
    std::fs::write(Path::new(&out_dir).join("deploy.json"), report.to_string())?;
    let arch = &session.ctx.arch;
    let packed = PackedModel::from_snapshot(arch, &model)?;
    let cgmqm = Path::new(&out_dir).join("deploy.cgmqm");
    let packed_bytes = packed.save(&cgmqm)?;
    println!(
        "\npacked artifact: {} ({:.1} KiB; fp32 weights were {:.1} KiB)",
        cgmqm.display(),
        packed_bytes as f64 / 1024.0,
        report.get("fp32_weight_memory_bytes")?.as_f64()? / 1024.0
    );
    println!("per-layer shipped formats:");
    for layer in report.get("layers")?.as_arr()? {
        println!(
            "  {:<6} histogram {:?}  (packed {:.1} KiB)",
            layer.get("name")?.as_str()?,
            layer.get("weight_bit_histogram")?,
            layer.get("packed_weight_bytes")?.as_f64()? / 1024.0
        );
    }

    // ---- 3. Infer: load the artifact and run it ------------------------
    let engine = Engine::load(&cgmqm)?;
    let n = 256.min(session.ctx.test_data.len());
    let in_len = engine.input_len();
    let xs = &session.ctx.test_data.images[..n * in_len];
    let labels = &session.ctx.test_data.labels[..n];

    // Golden check: the packed engine must reproduce the host fake-quant
    // forward bit-for-bit on the shipped snapshot.
    let packed_logits = engine.infer_batch(xs, n)?;
    let reference = cgmq::deploy::reference::fake_quant_logits(
        arch,
        &model.params,
        &model.betas_w,
        &model.betas_a,
        &model.gates,
        xs,
        n,
    )?;
    assert_eq!(packed_logits.len(), reference.len());
    assert!(
        packed_logits.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
        "packed engine drifted from the fake-quant reference"
    );
    let preds = engine.predict_batch(xs, n)?;
    let correct = preds.iter().zip(labels).filter(|&(&p, &l)| p as i32 == l).count();
    println!(
        "\npacked-engine accuracy on {} held-out samples: {:.2}% (bit-exact vs fake-quant eval)",
        n,
        100.0 * correct as f64 / n as f64
    );

    // ---- 4. Serve: batched inference through the request batcher -------
    let mut batcher = RequestBatcher::new(
        Engine::load(&cgmqm)?,
        BatchConfig { max_batch: 32, max_delay: Duration::from_micros(200) },
    )?;
    let t0 = Instant::now();
    let mut served = 0usize;
    for i in 0..n {
        let now = Instant::now();
        served += batcher.submit_at(xs[i * in_len..(i + 1) * in_len].to_vec(), now)?.len();
        served += batcher.poll_at(Instant::now())?.len();
    }
    served += batcher.flush_at(Instant::now())?.len();
    let batched_rps = n as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(served, n);

    let single = Engine::load(&cgmqm)?.with_mode(DecodeMode::Streaming);
    let t0 = Instant::now();
    for i in 0..n {
        std::hint::black_box(single.infer(&xs[i * in_len..(i + 1) * in_len])?);
    }
    let single_rps = n as f64 / t0.elapsed().as_secs_f64();
    println!(
        "serve path: {:.0} req/s batched vs {:.0} req/s one-by-one ({:.1}x, mean batch {:.1})",
        batched_rps,
        single_rps,
        batched_rps / single_rps,
        batcher.stats().mean_batch()
    );

    // ---- 5. Scale out: the sharded worker pool --------------------------
    // One engine, shared by N threads (`infer_batch` takes `&self`; the
    // decoded-weight cache is lock-free). Requests are routed round-robin
    // into per-shard batching queues with the same flush triggers.
    let shared = Arc::new(Engine::load(&cgmqm)?);
    let workers = cgmq::deploy::default_workers();
    let mut pool = WorkerPool::new(
        Arc::clone(&shared),
        PoolConfig {
            workers,
            batch: BatchConfig { max_batch: 32, max_delay: Duration::from_micros(200) },
            queue_cap: 0,
        },
    )?;
    let t0 = Instant::now();
    for i in 0..n {
        pool.submit(xs[i * in_len..(i + 1) * in_len].to_vec())?;
    }
    let (completions, shard_stats) = pool.shutdown()?;
    let pooled_rps = n as f64 / t0.elapsed().as_secs_f64();
    assert_eq!(completions.len(), n);
    // The pool serves the same bits the single-threaded engine does.
    for c in &completions {
        let direct = shared.infer(&xs[c.id as usize * in_len..(c.id as usize + 1) * in_len])?;
        assert!(c.logits.iter().zip(&direct).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
    println!(
        "pooled serve path: {:.0} req/s across {} workers ({:.1}x vs one-by-one, {} shard flushes)",
        pooled_rps,
        workers,
        pooled_rps / single_rps,
        shard_stats.iter().map(|s| s.flushes).sum::<u64>()
    );

    // ---- 6. Route: two budget variants behind one front, swapped live --
    // CGMQ's deliverable is a *family* of models, one per compute budget.
    // Stand a second, looser tier next to the trained one — the same
    // delivered weights at uniform 8 bits (a real deployment would pin
    // each tier with its own CGMQ run; reusing the weights keeps this
    // example to one training run) — and serve both behind one router
    // with bounded shard queues.
    let mut gates8 = GateSet::new(arch, Granularity::Layer);
    for t in gates8.gates_w.iter_mut().chain(gates8.gates_a.iter_mut()) {
        t.data_mut()[0] = gate_for_bits(8);
    }
    let loose =
        PackedModel::from_state(arch, &model.params, &model.betas_w, &model.betas_a, &gates8)?;
    let loose_ref = cgmq::deploy::reference::fake_quant_logits(
        arch,
        &model.params,
        &model.betas_w,
        &model.betas_a,
        &gates8,
        xs,
        n,
    )?;
    let c = shared.num_classes();

    let mut router = Router::new(PoolConfig {
        workers: 2,
        batch: BatchConfig { max_batch: 32, max_delay: Duration::from_micros(200) },
        // Bound each shard's in-flight depth: overload is *shed* (a
        // network front would answer 429), never queued without limit.
        queue_cap: 128,
    });
    router.add_model("tight", Arc::clone(&shared))?;
    router.add_model("loose", Arc::new(Engine::new(loose)?))?;

    // Alternate the tiers; halfway through, roll "loose" forward to the
    // tight engine — a zero-downtime hot swap (replacement pool spawned
    // and preloaded first, old pool drained, nothing dropped).
    let mut routed: std::collections::BTreeMap<&str, Vec<usize>> =
        [("tight", Vec::new()), ("loose", Vec::new())].into();
    let mut pre_swap_accepted = 0;
    for i in 0..n {
        if i == n / 2 {
            pre_swap_accepted = router.stats("loose")?.accepted;
            router.swap_model("loose", Arc::clone(&shared))?;
        }
        let key = if i % 2 == 0 { "tight" } else { "loose" };
        match router.try_submit(key, xs[i * in_len..(i + 1) * in_len].to_vec())? {
            Submission::Accepted { .. } => routed.get_mut(key).unwrap().push(i),
            Submission::Shed { .. } => {} // admission refused; try the other tier or back off
        }
    }
    let reports = router.shutdown()?;
    for (key, report) in &reports {
        let stats = report.stats;
        assert!(stats.consistent(), "{key}: {stats:?}");
        assert_eq!(
            stats.completed, stats.accepted,
            "{key}: every accepted request completes, even across the swap"
        );
        // Per-model bit-identity: each completion matches the reference
        // forward of the engine *version* that served it.
        for comp in &report.completions {
            let sample = routed[key.as_str()][comp.id as usize];
            let served_by_loose = key == "loose" && comp.id < pre_swap_accepted;
            let expect = if served_by_loose { &loose_ref } else { &packed_logits };
            let row = &expect[sample * c..(sample + 1) * c];
            assert!(
                comp.logits.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{key} request {} drifted from its engine's reference",
                comp.id
            );
        }
        println!(
            "router '{key}': {} accepted, {} shed, {} swap(s) — bit-exact per engine version",
            stats.accepted, stats.shed, stats.swaps
        );
    }

    // ---- 7. Serve over the network: the HTTP front -----------------------
    // The last rung: the router behind a real (std-only) HTTP/1.1 listener
    // on an ephemeral loopback port. Requests arrive as JSON, overload
    // would be answered 429 + Retry-After, and the reply logits are the
    // same bits the engine produces in-process.
    let server = cgmq::deploy::net::Server::bind(
        "127.0.0.1:0",
        vec![("tight".to_string(), Arc::clone(&shared))],
        cgmq::deploy::net::ServerConfig {
            pool: PoolConfig {
                workers: 2,
                batch: BatchConfig { max_batch: 32, max_delay: Duration::from_micros(200) },
                queue_cap: 128,
            },
            ..cgmq::deploy::net::ServerConfig::default()
        },
    )?;
    let addr = server.local_addr().to_string();
    let mut client = cgmq::deploy::net::HttpClient::connect(&addr, Duration::from_secs(5))?;
    let n_http = 16.min(n);
    for i in 0..n_http {
        use cgmq::util::json::Json;
        let x = &xs[i * in_len..(i + 1) * in_len];
        let body = Json::obj(vec![("x", Json::arr_f32(x))]).to_string();
        let (status, text) = client.request("POST", "/v1/models/tight/infer", Some(&body))?;
        anyhow::ensure!(status == 200, "HTTP {status}: {text}");
        let logits = cgmq::util::json::parse(&text)?.get("logits")?.as_f32_vec()?;
        let row = &packed_logits[i * c..(i + 1) * c];
        assert!(
            logits.iter().zip(row).all(|(a, b)| a.to_bits() == b.to_bits()),
            "HTTP request {i} drifted from the in-process engine"
        );
    }
    let (status, stats_body) = client.request("GET", "/stats", None)?;
    anyhow::ensure!(status == 200, "HTTP {status}: {stats_body}");
    // The telemetry spine exposes the same counters as a Prometheus
    // scrape; the request series must already account for every infer.
    let (status, metrics_body) = client.request("GET", "/metrics", None)?;
    anyhow::ensure!(status == 200, "HTTP {status}: {metrics_body}");
    let series = cgmq::bench_harness::parse_prometheus(&metrics_body);
    let ok_requests = series
        .get("cgmq_requests_total{model=\"tight\",status=\"200\"}")
        .copied()
        .unwrap_or(0.0) as usize;
    anyhow::ensure!(
        ok_requests == n_http,
        "/metrics counted {ok_requests} OK requests, expected {n_http}"
    );
    drop(client);
    let net_report = server.finish()?;
    net_report.verify_drained()?;
    println!(
        "network front on {addr}: {} requests served over HTTP, bit-exact, drained cleanly",
        net_report.served
    );
    println!(
        "  /metrics agrees: cgmq_requests_total{{model=\"tight\",status=\"200\"}} = {ok_requests}"
    );

    println!("\nwrote {}/deploy.json, deploy.ckpt and deploy.cgmqm", out_dir);
    Ok(())
}
