//! Edge-deployment walkthrough: train under a device budget, export the
//! bit-width assignment, and report what actually ships.
//!
//!     cargo run --release --example edge_deployment
//!
//! This is the workflow the paper's introduction motivates: a practitioner
//! has a device with a hard compute budget (here: 1.4% of fp32 bit-ops),
//! runs the CGMQ pipeline once, and gets a mixed-precision model that
//! provably fits, plus the per-layer integer formats to provision. The
//! `BestSnapshotSaver` observer keeps the current deliverable on disk
//! throughout the run — a crash after the first satisfying epoch still
//! leaves a shippable model.

use cgmq::config::Config;
use cgmq::quant;
use cgmq::session::{BestSnapshotSaver, SessionBuilder};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    cfg.arch = "mlp".into();
    cfg.train_size = 2_000;
    cfg.test_size = 512;
    cfg.pretrain_epochs = 3;
    cfg.range_epochs = 1;
    cfg.cgmq_epochs = 10;
    cfg.granularity = cgmq::gates::Granularity::Individual;
    cfg.bound_rbop_percent = 1.40;
    cfg.gate_lr_scale = 10.0;
    cfg.out_dir = "runs/edge_deployment".into();

    println!("device budget: {:.2}% of fp32 bit-operations\n", cfg.bound_rbop_percent);
    let out_dir = cfg.out_dir.clone();
    let ckpt = std::path::Path::new(&out_dir).join("deploy.ckpt");
    std::fs::create_dir_all(&out_dir)?;
    let cfg_export = cfg.clone();
    let mut session = SessionBuilder::new(cfg)
        .paper_pipeline()
        .observer(BestSnapshotSaver::new(&ckpt))
        .build()?;
    session.run()?;
    let result = session.result()?;
    let model = session.final_model()?;

    // Export: per-layer bit histograms + memory (the deployment report).
    let report = cgmq::baselines::export_report(&cfg_export, &ckpt)?;
    std::fs::write(std::path::Path::new(&out_dir).join("deploy.json"), report.to_string())?;

    println!("accuracy: {:.2}% (float was {:.2}%)", 100.0 * result.quant_acc,
        100.0 * result.float_acc);
    println!("RBOP: {:.3}% <= bound {:.2}%  [guaranteed]", result.rbop_percent,
        result.bound_rbop_percent);
    println!(
        "weight memory: {:.1} KiB (fp32 was {:.1} KiB)",
        report.get("total_weight_memory_bytes")?.as_f64()? / 1024.0,
        report.get("fp32_weight_memory_bytes")?.as_f64()? / 1024.0
    );
    println!("\nper-layer shipped formats:");
    for layer in report.get("layers")?.as_arr()? {
        println!(
            "  {:<6} histogram {:?}  ({:.1} KiB)",
            layer.get("name")?.as_str()?,
            layer.get("weight_bit_histogram")?,
            layer.get("weight_memory_bytes")?.as_f64()? / 1024.0
        );
    }

    // Show a few exported integer codes (what an int kernel would consume).
    println!("\nsample integer codes (fc1, 4-bit grid if assigned):");
    let w = &model.params[0];
    let g = &model.gates.materialize_all_w(&session.ctx.arch)[0];
    let beta = model.betas_w.data()[0];
    for i in 0..5 {
        let bits = quant::transform_t(g.data()[i]);
        if bits < quant::IDENTITY_BITS && bits > 0 {
            let (code, scale) = quant::integer_code(w.data()[i], bits, beta, true);
            println!("  w[{i}] = {:+.5} -> int{bits} code {code:+} x scale {scale:.5}",
                w.data()[i]);
        } else {
            println!("  w[{i}] = {:+.5} -> kept at {bits} bits", w.data()[i]);
        }
    }
    println!("\nwrote {}/deploy.json and deploy.ckpt", out_dir);
    Ok(())
}
