//! End-to-end driver (DESIGN.md deliverable): the paper's headline
//! experiment on the paper's model — LeNet-5, quantized under a 0.40% BOP
//! bound, full four-stage pipeline, loss curve logged per epoch.
//!
//!     cargo run --release --example mnist_cgmq [-- <train_size> <cgmq_epochs>]
//!
//! Uses SynthMNIST (DESIGN.md §2 substitution); drop the four MNIST IDX
//! files into ./mnist and switch `cfg.data` to run the genuine dataset.
//! The run is recorded in EXPERIMENTS.md.

use cgmq::config::{Config, DataSource};
use cgmq::session::{JsonlMetricsObserver, SessionBuilder};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let train_size: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(4_000);
    let cgmq_epochs: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);

    let mut cfg = Config {
        arch: "lenet5".into(),
        train_size,
        test_size: 1_000,
        pretrain_epochs: 6,
        range_epochs: 1,
        cgmq_epochs,
        bound_rbop_percent: 0.40, // the paper's tightest bound
        gate_lr_scale: 10.0,      // schedule-compensated (see Config docs)
        out_dir: "runs/mnist_cgmq".into(),
        ..Config::default()
    };
    cfg.lr_gates = Config::paper_gate_lr(cfg.direction) * cfg.gate_lr_scale;
    if cgmq::data::idx::mnist_available(std::path::Path::new("mnist")) {
        println!("found real MNIST in ./mnist — using it");
        cfg.data = DataSource::Mnist("mnist".into());
        cfg.train_size = 60_000;
        cfg.test_size = 10_000;
    }

    println!(
        "LeNet-5 ({} params) | {} train / {} test | bound {:.2}% RBOP",
        cgmq::model::lenet5().n_params(),
        cfg.train_size,
        cfg.test_size,
        cfg.bound_rbop_percent
    );

    let out_dir = cfg.out_dir.clone();
    let dir = std::path::Path::new(&out_dir);
    let mut session = SessionBuilder::new(cfg)
        .paper_pipeline()
        .observer(JsonlMetricsObserver::create(dir.join("epochs.jsonl"))?)
        .build()?;
    session.run()?;
    let result = session.result()?;

    println!("\nphase      epoch   loss      acc      RBOP%    sat");
    for r in &session.metrics().records {
        println!(
            "{:<10} {:>5}  {:>7.4}  {:>6.2}%  {:>7.3}  {}",
            r.phase, r.epoch, r.train_loss, 100.0 * r.test_acc, r.rbop_percent, r.sat
        );
    }

    println!("\n=== paper-format row (Table 1 analogue) ===");
    println!("| FP32 | -           | {:.2} | 100  | 100  |", 100.0 * result.float_acc);
    println!(
        "| CGMQ | {}, {} | {:.2} | {:.2} | {:.2} |",
        session.ctx.cfg.direction.label(),
        session.ctx.cfg.granularity.label(),
        100.0 * result.quant_acc,
        result.rbop_percent,
        result.bound_rbop_percent
    );
    assert!(result.satisfied);

    session.metrics().write_csv(&dir.join("epochs.csv"))?;
    std::fs::write(dir.join("result.json"), result.to_json().to_string())?;
    session.final_model()?.save(&dir.join("model.ckpt"), session.ctx.arch.name)?;
    println!("\nwrote {}/epochs.csv, epochs.jsonl, result.json, model.ckpt", out_dir);

    // Runtime execution statistics (per artifact).
    println!("\nartifact execution stats:");
    for (name, s) in session.ctx.artifacts.all_stats() {
        if s.calls > 0 {
            println!(
                "  {:<22} {:>6} calls  {:>8.1} ms/call",
                name,
                s.calls,
                1e3 * s.total_secs / s.calls as f64
            );
        }
    }
    Ok(())
}
