//! Baseline comparison (experiment A2): CGMQ vs the penalty method
//! (DQ-style), the Bayesian-Bits-like decay proxy, uniform fixed-bit QAT
//! and the myQASR heuristic — all on the same substrate, same data, same
//! pretrained model.
//!
//!     cargo run --release --example baseline_comparison
//!
//! Every method is a stage sequence over the same `TrainCtx`: CGMQ is the
//! paper pipeline, fixed-bit QAT is `PinGates + Finetune`, myQASR is its
//! own custom stage — the staged API makes the comparison a matter of
//! swapping the tail of the pipeline.
//!
//! The point reproduced from the paper's Section 3: CGMQ hits the budget in
//! ONE training run with NO hyperparameter; the penalty method's outcome
//! swings with λ (too small -> budget violated; too large -> accuracy
//! wasted), and the BB-style proxy needs an outer tuning loop of full
//! trainings.

use cgmq::baselines::{bb_proxy, fixed_qat, myqasr, penalty};
use cgmq::bench_harness;
use cgmq::config::Config;
use cgmq::session::TrainCtx;

fn base_cfg() -> Config {
    Config {
        arch: "mlp".into(),
        train_size: 2_000,
        test_size: 512,
        pretrain_epochs: 3,
        range_epochs: 1,
        cgmq_epochs: 10,
        bound_rbop_percent: 0.90,
        gate_lr_scale: 10.0,
        out_dir: "runs/baseline_comparison".into(),
        ..Config::default()
    }
}

/// Phase-3 input state shared by all baselines: loaded from the cached
/// pretrained checkpoint, calibrated, ranges learned.
fn fresh(cfg: &Config, ckpt: &std::path::Path) -> anyhow::Result<TrainCtx> {
    Ok(bench_harness::resumed_session(cfg, ckpt)?.into_ctx())
}

fn main() -> anyhow::Result<()> {
    let cfg = base_cfg();
    let ckpt = bench_harness::ensure_pretrained(&cfg)?;
    println!("bound: {:.2}% RBOP | method                      | acc    | RBOP   | sat | trainings", cfg.bound_rbop_percent);
    println!("{}", "-".repeat(95));

    // CGMQ — one run, no hyperparameter.
    let r = bench_harness::run_row(&cfg, cfg.direction, cfg.granularity, cfg.bound_rbop_percent)?;
    println!(
        "                     CGMQ ({}, {})          | {:5.2}% | {:5.2}% | {}   | 1",
        cfg.direction.label(),
        cfg.granularity.label(),
        100.0 * r.quant_acc,
        r.rbop_percent,
        r.satisfied as u8
    );

    // Penalty method at several λ — the tuning burden made visible.
    for lambda in [0.01f32, 0.1, 1.0] {
        let mut ctx = fresh(&cfg, &ckpt)?;
        let p = penalty::run(&mut ctx, lambda, cfg.cgmq_epochs)?;
        println!(
            "                     penalty λ={lambda:<6}            | {:5.2}% | {:5.2}% | {}   | 1",
            100.0 * p.test_acc,
            p.rbop_percent,
            p.satisfied as u8
        );
    }

    // BB-style proxy — outer bisection of full trainings.
    let cfg2 = cfg.clone();
    let ckpt2 = ckpt.clone();
    let bb = bb_proxy::tune_mu(
        move || fresh(&cfg2, &ckpt2),
        cfg.cgmq_epochs,
        4, // practitioner patience
    )?;
    println!(
        "                     bb_proxy μ={:<9.4}        | {:5.2}% | {:5.2}% | {}   | {}",
        bb.mu,
        100.0 * bb.test_acc,
        bb.rbop_percent,
        bb.satisfied as u8,
        bb.trainings
    );

    // Uniform fixed-bit QAT — no budget targeting at all.
    for bits in [2u32, 4] {
        let mut ctx = fresh(&cfg, &ckpt)?;
        let f = fixed_qat::run(&mut ctx, bits, cfg.cgmq_epochs)?;
        let sat = f.rbop_percent <= cfg.bound_rbop_percent;
        println!(
            "                     fixed {bits}-bit QAT            | {:5.2}% | {:5.2}% | {}   | 1",
            100.0 * f.test_acc,
            f.rbop_percent,
            sat as u8
        );
    }

    // myQASR heuristic — search-free descent + finetune.
    let mut ctx = fresh(&cfg, &ckpt)?;
    let m = myqasr::run(&mut ctx, cfg.cgmq_epochs)?;
    println!(
        "                     myQASR                     | {:5.2}% | {:5.2}% | {}   | 1   {:?}",
        100.0 * m.test_acc,
        m.rbop_percent,
        m.satisfied as u8,
        m.assignment
    );
    Ok(())
}
