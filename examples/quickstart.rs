//! Quickstart: the smallest end-to-end CGMQ run (MLP on SynthMNIST).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the public API surface: config -> SessionBuilder -> staged
//! pipeline -> constraint-satisfying model, plus a layer-by-layer
//! fake-quantization trace (the code form of the paper's Fig. 1).

use cgmq::config::Config;
use cgmq::quant;
use cgmq::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    // 1. Configure a small run. Everything here also lives in configs/*.toml.
    let cfg = Config {
        arch: "mlp".into(),
        train_size: 2_000,
        test_size: 512,
        pretrain_epochs: 3,
        range_epochs: 1,
        cgmq_epochs: 8,
        bound_rbop_percent: 0.90, // deploy budget: 0.9% of fp32 bit-ops
        out_dir: "runs/quickstart".into(),
        ..Config::default()
    };

    // 2. Fig. 1 as code: what one layer's fake quantization does.
    println!("== Fake quantization (paper Eq. 1/3/4) ==");
    let beta = 1.0;
    for (g, _what) in [(0.7, "2-bit"), (2.5, "8-bit"), (5.5, "32-bit")] {
        let x = 0.337f32;
        let q = quant::gated_quantize(x, g, beta, true);
        println!("  gate {g:>3}: T(g) = {:>2} bits, Q({x}) = {q}", quant::transform_t(g));
    }

    // 3. Train: the paper pipeline is a stage sequence —
    //    Pretrain -> Calibrate -> RangeLearn -> CgmqLoop.
    println!("\n== Training (4 stages) ==");
    let mut session = SessionBuilder::new(cfg).paper_pipeline().build()?;
    for report in session.run()? {
        println!(
            "  stage {:<10} {:>3} epochs in {:.1}s",
            report.stage, report.epochs_run, report.secs
        );
    }
    let result = session.result()?;

    // 4. The guarantee: the delivered model satisfies the bound.
    println!("\n== Result ==");
    println!("float accuracy      : {:.2}%", 100.0 * result.float_acc);
    println!("quantized accuracy  : {:.2}%", 100.0 * result.quant_acc);
    println!("relative BOPs       : {:.3}% (bound {:.2}%)", result.rbop_percent,
        result.bound_rbop_percent);
    println!("constraint satisfied: {}", result.satisfied);
    println!("mean weight bits    : {:.2}", result.mean_weight_bits);
    println!("\nRBOP trace per epoch: {:?}",
        result.rbop_trace.iter().map(|r| format!("{r:.2}")).collect::<Vec<_>>());
    assert!(result.satisfied, "CGMQ must deliver a constraint-satisfying model");
    Ok(())
}
