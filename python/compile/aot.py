"""AOT compile path: lower every L2 step function to HLO *text* artifacts.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 Rust crate links) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Alongside the .hlo.txt files a ``manifest.json`` records, for every
artifact, the exact input/output order, names, shapes and dtypes. The Rust
``model`` registry asserts its own expectations against the manifest at
startup, so a drift between the two layers fails fast instead of silently
feeding tensors in the wrong slot.

Also emits ``goldens.json``: quantizer/dir test vectors and SynthMNIST
sample hashes that the Rust unit tests replay (cross-language oracle).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data_synth, model
from .arch import ARCHS, ArchSpec
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Artifact argument builders (shapes only — lowering is shape-polymorphic-free)
# --------------------------------------------------------------------------


def _f32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _i32(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def _param_specs(arch: ArchSpec) -> List[Tuple[str, jax.ShapeDtypeStruct]]:
    out = []
    for layer in arch.layers:
        out.append((f"{layer.name}.w", _f32(layer.w_shape)))
        out.append((f"{layer.name}.b", _f32(layer.b_shape)))
    return out


def _gate_specs(arch: ArchSpec) -> List[Tuple[str, jax.ShapeDtypeStruct]]:
    out = [(f"{l.name}.gw", _f32(l.w_shape)) for l in arch.layers]
    out += [(f"{l.name}.ga", _f32(l.act_shape)) for l in arch.quant_act_layers]
    return out


def _range_specs(arch: ArchSpec) -> List[Tuple[str, jax.ShapeDtypeStruct]]:
    return [
        ("betas_w", _f32((len(arch.layers),))),
        ("betas_a", _f32((len(arch.quant_act_layers),))),
    ]


def artifact_plan(arch: ArchSpec):
    """(name, fn, inputs, output_names) for every artifact of one arch."""
    x_train = (f"x", _f32((arch.train_batch,) + arch.input_shape))
    y_train = (f"y", _i32((arch.train_batch,)))
    x_eval = (f"x", _f32((arch.eval_batch,) + arch.input_shape))
    params = _param_specs(arch)
    ranges = _range_specs(arch)
    gates = _gate_specs(arch)
    pg = [f"grad.{n}" for n, _ in params]
    act_layers = arch.quant_act_layers

    plans = []
    plans.append((
        f"{arch.name}_float_step",
        model.make_float_step(arch),
        params + [x_train, y_train],
        ["loss"] + pg,
    ))
    plans.append((
        f"{arch.name}_qat_step",
        model.make_qat_step(arch),
        params + ranges + gates + [x_train, y_train],
        ["loss"] + pg + ["grad.betas_w", "grad.betas_a"]
        + [f"act_grad.{l.name}" for l in act_layers]
        + [f"act_mean.{l.name}" for l in act_layers],
    ))
    plans.append((
        f"{arch.name}_eval",
        model.make_eval(arch),
        params + ranges + gates + [x_eval],
        ["logits"],
    ))
    plans.append((
        f"{arch.name}_eval_float",
        model.make_eval_float(arch),
        params + [x_eval],
        ["logits"],
    ))
    plans.append((
        f"{arch.name}_calibrate",
        model.make_calibrate(arch),
        params + [x_train],
        ["w_maxes", "act_maxes", "logit_mean"],
    ))
    return plans


def lower_artifact(fn: Callable, inputs: Sequence[Tuple[str, jax.ShapeDtypeStruct]]) -> str:
    lowered = jax.jit(fn).lower(*[s for _, s in inputs])
    return to_hlo_text(lowered)


# --------------------------------------------------------------------------
# Goldens for the Rust-side oracle tests
# --------------------------------------------------------------------------


def _quantizer_goldens() -> dict:
    rng = np.random.default_rng(7)
    x = rng.normal(0.0, 0.6, size=(64,)).astype(np.float32)
    g = rng.uniform(-0.5, 5.5, size=(64,)).astype(np.float32)
    beta = 1.3
    cases = {}
    for bits in ref.BIT_LEVELS:
        for signed in (True, False):
            q = np.asarray(ref.quantize(jnp.asarray(x), bits, beta, signed))
            cases[f"q_b{bits}_{'s' if signed else 'u'}"] = q.tolist()
    gated_s = np.asarray(ref.gated_quantize(jnp.asarray(x), jnp.asarray(g), beta, True))
    gated_u = np.asarray(ref.gated_quantize(jnp.asarray(x), jnp.asarray(g), beta, False))
    return {
        "x": x.tolist(),
        "g": g.tolist(),
        "beta": beta,
        "bit_levels": list(ref.BIT_LEVELS),
        "T": np.asarray(ref.transform_T(jnp.asarray(g))).tolist(),
        "cases": cases,
        "gated_signed": gated_s.tolist(),
        "gated_unsigned": gated_u.tolist(),
    }


def _synth_goldens(seed: int = 42, n: int = 6) -> dict:
    samples = []
    for i in range(n):
        img, lab = data_synth.render_digit(seed, i)
        samples.append({
            "index": i,
            "label": lab,
            "sum": float(np.sum(img)),
            "pixels": img.reshape(-1)[:64].astype(float).tolist(),
        })
    return {"seed": seed, "samples": samples}


def _bop_goldens() -> dict:
    """Per-arch MAC counts + the all-2-bit RBOP floor (paper: 0.392% for LeNet-5)."""
    out = {}
    for name, arch in ARCHS.items():
        layers = []
        for l in arch.layers:
            layers.append({"name": l.name, "macs": l.macs, "fan_in": l.fan_in})
        # BOP model (DESIGN.md §7): output-activation bit-widths, output layer
        # excluded from both numerator and denominator.
        counted = arch.layers[:-1]
        fp32 = sum(l.macs * 32 * 32 for l in counted)
        floor = sum(l.macs * 2 * 2 for l in counted)
        out[name] = {
            "layers": layers,
            "fp32_bops": fp32,
            "floor_bops": floor,
            "floor_rbop_percent": 100.0 * floor / fp32,
        }
    return out


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--archs", nargs="*", default=list(ARCHS))
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text-v1", "artifacts": {}}
    for arch_name in args.archs:
        arch = ARCHS[arch_name]
        for name, fn, inputs, out_names in artifact_plan(arch):
            print(f"[aot] lowering {name} ...", flush=True)
            text = lower_artifact(fn, inputs)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"][name] = {
                "arch": arch_name,
                "file": f"{name}.hlo.txt",
                "inputs": [
                    {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                    for n, s in inputs
                ],
                "outputs": out_names,
            }
            print(f"[aot]   wrote {path} ({len(text)} chars)")

    manifest["archs"] = {
        name: {
            "input_shape": list(a.input_shape),
            "train_batch": a.train_batch,
            "eval_batch": a.eval_batch,
            "input_bits": a.input_bits,
            "layers": [
                {
                    "name": l.name,
                    "kind": l.kind,
                    "w_shape": list(l.w_shape),
                    "b_shape": list(l.b_shape),
                    "act_shape": list(l.act_shape),
                    "pool": l.pool or 0,
                    "quant_act": l.quant_act,
                    "macs": l.macs,
                    "fan_in": l.fan_in,
                }
                for l in a.layers
            ],
        }
        for name, a in ARCHS.items()
        if name in args.archs
    }

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest.json ({len(manifest['artifacts'])} artifacts)")

    if not args.skip_goldens:
        goldens = {
            "quantizer": _quantizer_goldens(),
            "synth": _synth_goldens(),
            "bop": _bop_goldens(),
        }
        with open(os.path.join(args.out_dir, "goldens.json"), "w") as f:
            json.dump(goldens, f)
        print("[aot] wrote goldens.json")


if __name__ == "__main__":
    main()
