"""Canonical architecture specs shared by model.py, aot.py and (via
artifacts/manifest.json) the Rust ``model/`` registry.

Two architectures:

* ``lenet5`` — the paper's evaluation model (LeNet-5, Caffe variant, as used
  by Bayesian Bits): conv(20@5x5) -> pool -> conv(50@5x5) -> pool ->
  fc(500) -> fc(10). 431,080 parameters.
* ``mlp``    — a small 784-128-64-10 MLP used for CI-scale tests, examples
  and the quickstart.

Conventions (mirrored exactly in Rust):

* Layer order per layer: weight tensor then bias tensor.
* Conv weights are OIHW; dense weights are (in, out); activations NCHW.
* Every layer's weights are fake-quantized (gated); biases are never
  quantized (paper quantizes activations instead of biases).
* Every layer except the last has its (ReLU) activation fake-quantized,
  *before* pooling; the network output stays float (paper Section 4.2).
* The network input is quantized at a fixed 8 bits with range [-1, 1].
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str
    kind: str  # "conv" | "dense"
    w_shape: Tuple[int, ...]  # OIHW for conv, (in, out) for dense
    b_shape: Tuple[int, ...]
    act_shape: Tuple[int, ...]  # feature dims of the (pre-pool) activation
    pool: Optional[int] = None  # square max-pool window/stride after the act
    quant_act: bool = True  # last layer: False (output kept float)

    @property
    def macs(self) -> int:
        """Multiply-accumulates for one sample (BOP building block)."""
        if self.kind == "conv":
            o, i, kh, kw = self.w_shape
            _, oh, ow = self.act_shape
            return o * oh * ow * i * kh * kw
        fan_in, fan_out = self.w_shape
        return fan_in * fan_out

    @property
    def fan_in(self) -> int:
        if self.kind == "conv":
            _, i, kh, kw = self.w_shape
            return i * kh * kw
        return self.w_shape[0]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    input_shape: Tuple[int, ...]  # per-sample, no batch dim
    layers: Tuple[LayerSpec, ...]
    train_batch: int = 128
    eval_batch: int = 256
    input_bits: int = 8

    @property
    def quant_act_layers(self) -> List[LayerSpec]:
        return [l for l in self.layers if l.quant_act]

    def param_names(self) -> List[str]:
        out = []
        for l in self.layers:
            out += [f"{l.name}.w", f"{l.name}.b"]
        return out

    def param_shapes(self) -> List[Tuple[int, ...]]:
        out = []
        for l in self.layers:
            out += [l.w_shape, l.b_shape]
        return out

    def n_params(self) -> int:
        return sum(
            int(__import__("math").prod(s)) if s else 1 for s in self.param_shapes()
        )


LENET5 = ArchSpec(
    name="lenet5",
    input_shape=(1, 28, 28),
    layers=(
        LayerSpec("conv1", "conv", (20, 1, 5, 5), (20,), (20, 24, 24), pool=2),
        LayerSpec("conv2", "conv", (50, 20, 5, 5), (50,), (50, 8, 8), pool=2),
        LayerSpec("fc1", "dense", (800, 500), (500,), (500,)),
        LayerSpec("fc2", "dense", (500, 10), (10,), (10,), quant_act=False),
    ),
)

MLP = ArchSpec(
    name="mlp",
    input_shape=(784,),
    layers=(
        LayerSpec("fc1", "dense", (784, 128), (128,), (128,)),
        LayerSpec("fc2", "dense", (128, 64), (64,), (64,)),
        LayerSpec("fc3", "dense", (64, 10), (10,), (10,), quant_act=False),
    ),
)

ARCHS = {"lenet5": LENET5, "mlp": MLP}
