"""L2 perf audit: op-count + redundancy analysis of the lowered artifacts.

    cd python && python -m compile.audit [--artifacts ../artifacts]

Reads each artifact's HLO text and reports, per module:

* total instruction count and counts of the expensive op classes
  (convolution, dot, reduce-window, rng, while);
* fake-quantization cost: `round-nearest-*` instruction count. The Eq. 3
  decomposition needs exactly 4 rounds per quantized tensor (b = 2,4,8,16;
  b=32 is a clip) — more would mean XLA failed to CSE the shared
  clip/scale subexpressions or the graph recomputes a quantization;
* transcendental count (exp/log) — should be confined to the one softmax
  cross-entropy.

This is the audit the EXPERIMENTS.md §Perf L2 row quotes. Exits non-zero if
a redundancy invariant fails, so it can run as a build gate.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from collections import Counter

from .arch import ARCHS

EXPENSIVE = ("convolution", "dot", "reduce-window", "rng", "while", "sort")


def op_counts(hlo_text: str) -> Counter:
    counts: Counter = Counter()
    #   %name = type op-name(args), ...
    for m in re.finditer(r"=\s+[^=\s]+\s+([a-z0-9-]+)\(", hlo_text):
        counts[m.group(1)] += 1
    return counts


def round_call_sites(hlo_text: str) -> int:
    """Rounding cost = call sites of outlined round computations + inline ops.

    XLA outlines the repeated `round-nearest-even` into a shared called
    computation (CSE), so the raw instruction count under-reports; the true
    per-execution cost is the number of `to_apply=round.*` call sites plus
    any round instructions in the entry computation.
    """
    calls = len(re.findall(r"to_apply=%?round", hlo_text))
    inline = len(re.findall(r"round-nearest-(?:even|afz)\(", hlo_text))
    # the outlined body itself contains one instruction; don't double count
    bodies = len(re.findall(r"^%?round[0-9.]* \{|^round[0-9.]* \{", hlo_text, re.M))
    return calls + max(0, inline - bodies)


def expected_rounds(arch_name: str, artifact: str) -> int | None:
    """Expected round-nearest count for qat/eval artifacts of an arch.

    Quantized tensors: every layer's weights (L) + every quantized
    activation (La) + the 8-bit input (1 round). Weights/activations use the
    Eq. 3 decomposition (4 rounds: b=2,4,8,16); the input is a single Q at
    8 bit (1 round).
    """
    arch = ARCHS[arch_name]
    n_gated = len(arch.layers) + len(arch.quant_act_layers)
    if artifact.endswith("_qat_step") or artifact.endswith("_eval"):
        return 4 * n_gated + 1
    return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()

    failures = []
    names = sorted(
        f[: -len(".hlo.txt")]
        for f in os.listdir(args.artifacts)
        if f.endswith(".hlo.txt")
    )
    for name in names:
        text = open(os.path.join(args.artifacts, f"{name}.hlo.txt")).read()
        counts = op_counts(text)
        total = sum(counts.values())
        rounds = round_call_sites(text)
        exp_logs = counts.get("exponential", 0) + counts.get("log", 0)
        expensive = {op: counts[op] for op in EXPENSIVE if counts.get(op)}
        print(f"{name}: {total} instrs, rounds={rounds}, exp/log={exp_logs}, {expensive}")

        arch_name = name.split("_")[0]
        expect = expected_rounds(arch_name, name)
        if expect is not None and rounds != expect:
            failures.append(
                f"{name}: {rounds} round call-sites != expected {expect} — "
                "quantizer recomputation or a dropped FQ block"
            )
        # cross-entropy is the only transcendental user in step artifacts
        if name.endswith("_step") and exp_logs > 6:
            failures.append(f"{name}: {exp_logs} exp/log ops — more than softmax CE needs")

    if failures:
        print("\nAUDIT FAILURES:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\naudit OK: no quantizer recomputation, transcendentals confined to CE")
    return 0


if __name__ == "__main__":
    sys.exit(main())
