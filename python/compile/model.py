"""L2: the paper's models (LeNet-5 / MLP) fwd+bwd with CGMQ fake quantization.

Every function here is a pure jax function over explicit flat argument
lists (no pytrees at the boundary) so that aot.py can lower them to HLO
text with a stable, manifest-recorded argument order for the Rust runtime.

Step functions exported as artifacts:

* ``float_step``  — float pretraining: (params..., x, y) -> (loss, grads...)
* ``qat_step``    — the CGMQ inner step: quantized fwd/bwd returning the
  weight/range gradients for Adam *plus* the dir statistics the Rust
  coordinator needs (paper Section 2.3): batch-mean loss gradients w.r.t.
  each quantized activation (via zero "probes") and batch-mean activation
  values. Gates enter as tensors; T(g) is applied inside the graph, so the
  same compiled artifact serves per-layer and per-weight granularity.
* ``eval_logits`` / ``eval_logits_float`` — inference.
* ``calibrate``   — float forward returning per-layer max|activation| for
  range calibration (paper Section 2.4).

The per-weight loss gradients the dirs need are exactly the Adam weight
gradients (the loss is a batch mean), so they are not duplicated.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from .arch import ArchSpec
from .quantizer import gated_quantize_ste, quantize_input


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------


def _apply_layer(layer, h, wq, b):
    """Linear part of a layer with already-quantized weights."""
    if layer.kind == "conv":
        z = jax.lax.conv_general_dilated(
            h, wq, window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        return z + b[None, :, None, None]
    if h.ndim > 2:
        h = h.reshape(h.shape[0], -1)
    return h @ wq + b[None, :]


def _maxpool(a, k: int):
    return jax.lax.reduce_window(
        a, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, k, k), "VALID"
    )


def _cross_entropy(logits, y):
    """Mean cross-entropy over the batch; y is int32 class labels."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def forward_quantized(
    arch: ArchSpec,
    params: Sequence[jnp.ndarray],
    betas_w: jnp.ndarray,  # (L,)   per-layer weight range
    betas_a: jnp.ndarray,  # (La,)  per-quantized-activation-layer range
    gates_w: Sequence[jnp.ndarray],  # per layer, shaped like the weights
    gates_a: Sequence[jnp.ndarray],  # per act layer, shaped like act feature dims
    x: jnp.ndarray,
    probes: Sequence[jnp.ndarray] | None = None,
):
    """Fake-quantized forward pass (paper Fig. 1 applied at every layer).

    Returns (logits, act_means) where act_means[i] is the batch mean of the
    i-th quantized activation tensor (feature-dim shaped) — the dir2/dir3
    statistic.
    """
    h = quantize_input(x, bits=arch.input_bits)
    act_means: List[jnp.ndarray] = []
    ai = 0
    n_layers = len(arch.layers)
    for li, layer in enumerate(arch.layers):
        w, b = params[2 * li], params[2 * li + 1]
        wq = gated_quantize_ste(w, gates_w[li], betas_w[li], True)
        z = _apply_layer(layer, h, wq, b)
        if li == n_layers - 1:
            return z, act_means  # output layer: float logits, no activation FQ
        a = jax.nn.relu(z)
        # ReLU output is non-negative -> unsigned range [0, beta].
        ga_full = jnp.broadcast_to(gates_a[ai][None, ...], a.shape)
        aq = gated_quantize_ste(a, ga_full, betas_a[ai], False)
        if probes is not None:
            aq = aq + probes[ai][None, ...]
        act_means.append(jnp.mean(aq, axis=0))
        if layer.pool:
            aq = _maxpool(aq, layer.pool)
        h = aq
        ai += 1
    raise AssertionError("unreachable")


def forward_float(arch: ArchSpec, params: Sequence[jnp.ndarray], x: jnp.ndarray):
    """Plain float forward; also returns per-layer activations for calibration."""
    h = x
    acts: List[jnp.ndarray] = []
    n_layers = len(arch.layers)
    for li, layer in enumerate(arch.layers):
        w, b = params[2 * li], params[2 * li + 1]
        z = _apply_layer(layer, h, w, b)
        if li == n_layers - 1:
            return z, acts
        a = jax.nn.relu(z)
        acts.append(a)
        h = _maxpool(a, layer.pool) if layer.pool else a
    raise AssertionError("unreachable")


# --------------------------------------------------------------------------
# Exported step functions (flat-arg, lowered by aot.py)
# --------------------------------------------------------------------------


def make_float_step(arch: ArchSpec):
    n_p = 2 * len(arch.layers)

    def float_step(*args):
        params, (x, y) = list(args[:n_p]), args[n_p:]

        def loss_fn(params):
            logits, _ = forward_float(arch, params, x)
            return _cross_entropy(logits, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return tuple([loss] + list(grads))

    return float_step


def make_qat_step(arch: ArchSpec):
    n_p = 2 * len(arch.layers)
    n_l = len(arch.layers)
    n_a = len(arch.quant_act_layers)

    def qat_step(*args):
        i = 0
        params = list(args[i : i + n_p]); i += n_p
        betas_w = args[i]; i += 1
        betas_a = args[i]; i += 1
        gates_w = list(args[i : i + n_l]); i += n_l
        gates_a = list(args[i : i + n_a]); i += n_a
        x, y = args[i], args[i + 1]

        probes = [jnp.zeros(l.act_shape, jnp.float32) for l in arch.quant_act_layers]

        def loss_fn(params, betas_w, betas_a, probes):
            logits, act_means = forward_quantized(
                arch, params, betas_w, betas_a, gates_w, gates_a, x, probes
            )
            return _cross_entropy(logits, y), act_means

        (loss, act_means), grads = jax.value_and_grad(
            loss_fn, argnums=(0, 1, 2, 3), has_aux=True
        )(params, betas_w, betas_a, probes)
        g_params, g_bw, g_ba, g_probes = grads
        # Output order (manifest-recorded): loss, param grads, range grads,
        # per-activation batch-mean loss grads (dir statistic), act means.
        return tuple([loss] + list(g_params) + [g_bw, g_ba] + list(g_probes) + list(act_means))

    return qat_step


def make_eval(arch: ArchSpec):
    n_p = 2 * len(arch.layers)
    n_l = len(arch.layers)
    n_a = len(arch.quant_act_layers)

    def eval_logits(*args):
        i = 0
        params = list(args[i : i + n_p]); i += n_p
        betas_w = args[i]; i += 1
        betas_a = args[i]; i += 1
        gates_w = list(args[i : i + n_l]); i += n_l
        gates_a = list(args[i : i + n_a]); i += n_a
        x = args[i]
        logits, _ = forward_quantized(arch, params, betas_w, betas_a, gates_w, gates_a, x)
        return (logits,)

    return eval_logits


def make_eval_float(arch: ArchSpec):
    n_p = 2 * len(arch.layers)

    def eval_logits_float(*args):
        params, x = list(args[:n_p]), args[n_p]
        logits, _ = forward_float(arch, params, x)
        return (logits,)

    return eval_logits_float


def make_calibrate(arch: ArchSpec):
    """Float forward -> (w_maxes, act_maxes, logit_mean).

    logit_mean is a diagnostics scalar that also keeps every parameter
    (notably the last layer's bias, which the max statistics don't touch)
    alive in the lowered HLO — XLA prunes unused entry parameters, which
    would silently change the artifact's arity (see runtime::Executable).
    """
    n_p = 2 * len(arch.layers)

    def calibrate(*args):
        params, x = list(args[:n_p]), args[n_p]
        logits, acts = forward_float(arch, params, x)
        w_maxes = jnp.stack(
            [jnp.max(jnp.abs(params[2 * li])) for li in range(len(arch.layers))]
        )
        act_maxes = jnp.stack([jnp.max(jnp.abs(a)) for a in acts])
        return (w_maxes, act_maxes, jnp.mean(logits))

    return calibrate
