"""STE / range-gradient wrappers around the L1 Pallas kernels.

CGMQ's gradient conventions (paper Sections 2.2-2.3):

* **Values** flow through the round-to-nearest with the Straight-Through
  Estimator: identity inside the clipping range, zero outside.
* **Ranges** (the learnable beta of each tensor) get the LSQ/TQT-style
  gradient: for clipped elements d q / d beta = sign(boundary); for interior
  elements the scale-error term (q - v) / beta.
* **Gates** get NO gradient at all — the paper's whole point is that the
  staircase T(g) is non-differentiable and the gate update is driven by the
  Rust coordinator's `dir` rules instead. The gate argument is therefore a
  `jax.custom_vjp` non-diff argument in spirit: its cotangent is zero.

Forward primal values come from the Pallas kernels (fake_quant.py); the
backward rules are closed-form jnp and never re-enter Pallas.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import fake_quant, ref


# --------------------------------------------------------------------------
# Fixed-bit quantizer with STE (used for the 8-bit network input).
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def quantize_ste(x, beta, bits: int, signed: bool):
    return fake_quant.quantize_pallas(x, beta, bits=bits, signed=signed)


def _quantize_fwd(x, beta, bits, signed):
    q = fake_quant.quantize_pallas(x, beta, bits=bits, signed=signed)
    return q, (x, beta, q)


def _quantize_bwd(bits, signed, res, ct):
    x, beta, q = res
    beta = jnp.asarray(beta, jnp.float32)
    alpha = -beta if signed else jnp.zeros_like(beta)
    inside = jnp.logical_and(x >= alpha, x <= beta)
    gx = jnp.where(inside, ct, 0.0)
    # d q / d beta: +-1 on the clipped tails, scale-error term inside.
    v = ref.clip(x, alpha, beta)
    dq_dbeta = jnp.where(
        x > beta,
        1.0,
        jnp.where(x < alpha, -1.0 if signed else 0.0, (q - v) / jnp.maximum(beta, 1e-6)),
    )
    gbeta = jnp.sum(ct * dq_dbeta).reshape(jnp.shape(beta))
    return gx, gbeta


quantize_ste.defvjp(_quantize_fwd, _quantize_bwd)


# --------------------------------------------------------------------------
# Gated residual-decomposition quantizer (Eq. 3) with STE.
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def gated_quantize_ste(x, g, beta, signed: bool):
    """Fake-quantize ``x`` at the per-element bit-width T(g).

    Gradients: STE to ``x`` (masked to the clip range), LSQ-style to
    ``beta``, exactly zero to ``g`` (paper: gate updates use dir, not grad).
    """
    return fake_quant.gated_quantize_pallas(x, g, beta, signed=signed)


def _gated_fwd(x, g, beta, signed):
    q = fake_quant.gated_quantize_pallas(x, g, beta, signed=signed)
    return q, (x, g, beta, q)


def _gated_bwd(signed, res, ct):
    x, g, beta, q = res
    beta = jnp.asarray(beta, jnp.float32)
    alpha = -beta if signed else jnp.zeros_like(beta)
    inside = jnp.logical_and(x >= alpha, x <= beta)
    gx = jnp.where(inside, ct, 0.0)
    v = ref.clip(x, alpha, beta)
    dq_dbeta = jnp.where(
        x > beta,
        1.0,
        jnp.where(x < alpha, -1.0 if signed else 0.0, (q - v) / jnp.maximum(beta, 1e-6)),
    )
    gbeta = jnp.sum(ct * dq_dbeta).reshape(jnp.shape(beta))
    gg = jnp.zeros_like(g)  # gates carry no gradient by construction
    return gx, gg, gbeta


gated_quantize_ste.defvjp(_gated_fwd, _gated_bwd)


def quantize_input(x, bits: int = 8, beta: float = 1.0):
    """Fixed 8-bit input quantization (no learnable range, no gradient to beta)."""
    return quantize_ste(x, jax.lax.stop_gradient(jnp.float32(beta)), bits, True)
