"""L1: Pallas kernels for the CGMQ fake-quantization hot-spot + jnp oracle."""

from . import fake_quant, ref  # noqa: F401
