"""Pure-jnp reference oracle for the CGMQ quantization kernels.

Everything in this module is straight-line jax.numpy with no Pallas and no
custom gradients: it is the ground truth that ``fake_quant.py`` (the Pallas
L1 kernels) and the Rust ``quant/`` module are tested against.

Math follows the paper exactly:

* Eq. 1 — ``quantize``: power-of-2-range uniform quantizer
      Q(x, b, alpha, beta) = (beta-alpha)/(2^b-1)
                             * round( clip(x, alpha, beta) * (2^b-1)/(beta-alpha) )
  with alpha = -beta for signed tensors and alpha = 0 for unsigned ones.

* Eq. 2/3 — ``gated_quantize``: residual decomposition over
  B = {2, 4, 8, 16, 32} with binary gate functions
      G_b(g) = 1  iff  T(g) >= b
  nested as
      x_q = G2 * [x_2 + G4 * [e4 + G8 * [e8 + G16 * [e16 + G32 * e32]]]]
  where e_j = x_j - x_{j/2} is the residual quantization error.

* Eq. 4 — ``transform_T``: the staircase mapping gate value -> bit-width
      g <= 0 -> 0,  (0,1] -> 2,  (1,2] -> 4,  (2,3] -> 8,  (3,4] -> 16,  g > 4 -> 32.

Numerical conventions (mirrored bit-for-bit by the Pallas kernel and Rust):

* For b >= 24 the f32 grid has more levels than the mantissa can represent
  and the quantizer degenerates to ``clip`` — we implement that case
  explicitly instead of relying on float behaviour.
* The step size is floored at ``EPS_SCALE`` to keep beta == 0 finite.
* The rounded integer is saturated to the standard symmetric grid
  [-(2^(b-1)-1), 2^(b-1)-1] for signed ranges ([0, 2^b-1] unsigned). The
  raw Eq. 1 puts every clipped value exactly on a round-half tie
  (clip(x)=beta -> v/s = (2^b-1)/2), whose resolution is backend-dependent
  (round-half-even vs 1-ulp drift under fusion); saturation makes the
  quantizer bit-deterministic across eager jnp, Pallas, lowered HLO and
  Rust without changing any interior level.
"""

from __future__ import annotations

import jax.numpy as jnp

# Bit-widths of the residual decomposition (paper: B = {4,8,16,32} on top of
# the base 2-bit level).
BIT_LEVELS = (2, 4, 8, 16, 32)

# Step-size floor: keeps Q well-defined when a range collapses (beta == 0).
EPS_SCALE = 1e-12

# At and above this bit-width, f32 cannot represent the integer grid, and
# fake quantization is numerically the identity (after clipping).
IDENTITY_BITS = 24


def clip(x, alpha, beta):
    """clip_{[alpha, beta]}(x) from the paper."""
    return jnp.minimum(jnp.maximum(x, alpha), beta)


def quantize(x, bits: int, beta, signed: bool):
    """Eq. 1: fake-quantize ``x`` to ``bits`` bits on the range implied by beta.

    alpha = -beta when ``signed`` (tensor contains negative values), else 0,
    matching the paper's range convention (Section 2.1).
    """
    beta = jnp.asarray(beta, dtype=jnp.float32)
    alpha = -beta if signed else jnp.zeros_like(beta)
    v = clip(x, alpha, beta)
    if bits >= IDENTITY_BITS:
        return v
    levels = float(2**bits - 1)
    scale = jnp.maximum((beta - alpha) / levels, EPS_SCALE)
    n_max = float(2 ** (bits - 1) - 1) if signed else levels
    n_min = -n_max if signed else 0.0
    n = jnp.minimum(jnp.maximum(jnp.round(v / scale), n_min), n_max)
    return scale * n


def transform_T(g):
    """Eq. 4: staircase transform from gate value to bit-width."""
    g = jnp.asarray(g, dtype=jnp.float32)
    return jnp.where(
        g <= 0.0,
        0.0,
        jnp.where(
            g <= 1.0,
            2.0,
            jnp.where(g <= 2.0, 4.0, jnp.where(g <= 3.0, 8.0, jnp.where(g <= 4.0, 16.0, 32.0))),
        ),
    )


def gate_masks(g):
    """G_b(g) for b in BIT_LEVELS as f32 {0,1} masks (Section 2.1)."""
    t = transform_T(g)
    return tuple(jnp.asarray(t >= float(b), dtype=jnp.float32) for b in BIT_LEVELS)


def gated_quantize(x, g, beta, signed: bool):
    """Eq. 3: gated residual-decomposition quantizer.

    ``x`` and ``g`` must have the same shape; ``beta`` is a scalar
    (per-tensor range). Returns the fake-quantized tensor whose effective
    bit-width at each element is T(g) at that element.
    """
    q = {b: quantize(x, b, beta, signed) for b in BIT_LEVELS}
    m2, m4, m8, m16, m32 = gate_masks(g)
    e4 = q[4] - q[2]
    e8 = q[8] - q[4]
    e16 = q[16] - q[8]
    e32 = q[32] - q[16]
    return m2 * (q[2] + m4 * (e4 + m8 * (e8 + m16 * (e16 + m32 * e32))))


def quantize_input(x, bits: int = 8, beta: float = 1.0):
    """Fixed-precision input quantizer (paper Section 4.2: input held at 8 bit).

    The normalised input lives in [-1, 1], so the range is fixed and signed
    and carries no gradient (it models the sensor ADC).
    """
    return quantize(x, bits, jnp.float32(beta), signed=True)
