"""L1 Pallas kernels: the CGMQ fake-quantization hot-spot.

Two kernels:

* ``quantize_pallas``       — Eq. 1 fixed-bit-width fake quantizer.
* ``gated_quantize_pallas`` — Eq. 3 gated residual-decomposition quantizer
                              (the per-element mixed-precision hot path).

TPU-shaped design (see DESIGN.md §Hardware-Adaptation): the operation is
elementwise, so the kernel is tiled for VMEM with (BLOCK_ROWS, LANES) =
(256, 128) f32 blocks (128 KiB per operand block, lane-aligned). All five
residual levels are computed in-register per block, so HBM traffic is two
reads (x, g) and one write (out) per element. On this image Pallas runs
with ``interpret=True`` (the CPU PJRT plugin cannot execute Mosaic
custom-calls); the structure is what we optimise, the TPU numbers are
estimated in EXPERIMENTS.md §Perf.

The kernels carry no gradient rules: ``quantizer.py`` wraps them in
``jax.custom_vjp`` (STE for values, LSQ-style for ranges), so the backward
pass never re-enters Pallas.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM tile: 256 rows x 128 lanes of f32 = 128 KiB per operand block.
BLOCK_ROWS = 256
LANES = 128


def _staircase(g):
    """Eq. 4 transform written with jnp.where (identical to ref.transform_T)."""
    return jnp.where(
        g <= 0.0,
        0.0,
        jnp.where(
            g <= 1.0,
            2.0,
            jnp.where(g <= 2.0, 4.0, jnp.where(g <= 3.0, 8.0, jnp.where(g <= 4.0, 16.0, 32.0))),
        ),
    )


def _quantize_block(x, bits: int, alpha, beta, signed: bool):
    """Eq. 1 on an in-register block (static bit-width, saturated grid)."""
    v = jnp.minimum(jnp.maximum(x, alpha), beta)
    if bits >= ref.IDENTITY_BITS:
        return v
    levels = float(2**bits - 1)
    scale = jnp.maximum((beta - alpha) / levels, ref.EPS_SCALE)
    n_max = float(2 ** (bits - 1) - 1) if signed else levels
    n_min = -n_max if signed else 0.0
    n = jnp.minimum(jnp.maximum(jnp.round(v / scale), n_min), n_max)
    return scale * n


def _quantize_kernel(x_ref, beta_ref, o_ref, *, bits: int, signed: bool):
    x = x_ref[...]
    beta = beta_ref[0, 0]
    alpha = -beta if signed else jnp.float32(0.0)
    o_ref[...] = _quantize_block(x, bits, alpha, beta, signed)


def _gated_quantize_kernel(x_ref, g_ref, beta_ref, o_ref, *, signed: bool):
    """Eq. 3: all residual levels computed in-register on one VMEM block."""
    x = x_ref[...]
    g = g_ref[...]
    beta = beta_ref[0, 0]
    alpha = -beta if signed else jnp.float32(0.0)

    q2 = _quantize_block(x, 2, alpha, beta, signed)
    q4 = _quantize_block(x, 4, alpha, beta, signed)
    q8 = _quantize_block(x, 8, alpha, beta, signed)
    q16 = _quantize_block(x, 16, alpha, beta, signed)
    q32 = _quantize_block(x, 32, alpha, beta, signed)  # == clip(x)

    t = _staircase(g)
    m2 = (t >= 2.0).astype(jnp.float32)
    m4 = (t >= 4.0).astype(jnp.float32)
    m8 = (t >= 8.0).astype(jnp.float32)
    m16 = (t >= 16.0).astype(jnp.float32)
    m32 = (t >= 32.0).astype(jnp.float32)

    # Nested residual sum, Eq. 3.
    o_ref[...] = m2 * (
        q2 + m4 * ((q4 - q2) + m8 * ((q8 - q4) + m16 * ((q16 - q8) + m32 * (q32 - q16))))
    )


def _as_tiles(arr):
    """Flatten + zero-pad an arbitrary tensor to (rows, LANES) tiles.

    Returns (tiled, total_elements). Rows are padded to a multiple of
    BLOCK_ROWS so the BlockSpec grid divides evenly (the TPU constraint the
    structure is written against).
    """
    flat = arr.reshape(-1)
    n = flat.shape[0]
    tile = BLOCK_ROWS * LANES
    padded = ((n + tile - 1) // tile) * tile
    flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(-1, LANES), n


def _from_tiles(tiled, n, shape):
    return tiled.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("bits", "signed"))
def quantize_pallas(x, beta, *, bits: int, signed: bool):
    """Eq. 1 fake quantizer as a tiled Pallas call (forward values only)."""
    xt, n = _as_tiles(x)
    rows = xt.shape[0]
    beta2 = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits, signed=signed),
        out_shape=jax.ShapeDtypeStruct(xt.shape, jnp.float32),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xt, beta2)
    return _from_tiles(out, n, x.shape)


@functools.partial(jax.jit, static_argnames=("signed",))
def gated_quantize_pallas(x, g, beta, *, signed: bool):
    """Eq. 3 gated quantizer as a tiled Pallas call (forward values only).

    ``g`` must already be broadcast to ``x.shape`` (L2 does the broadcast so
    the kernel stays a pure same-shape elementwise map).
    """
    assert x.shape == g.shape, f"gate shape {g.shape} != value shape {x.shape}"
    xt, n = _as_tiles(x)
    gt, _ = _as_tiles(g)
    rows = xt.shape[0]
    beta2 = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_gated_quantize_kernel, signed=signed),
        out_shape=jax.ShapeDtypeStruct(xt.shape, jnp.float32),
        grid=(rows // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xt, gt, beta2)
    return _from_tiles(out, n, x.shape)
