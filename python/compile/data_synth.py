"""SynthMNIST: deterministic procedural 28x28 digit renderer.

This is the repo's substitution for MNIST (no network access in the build
environment — see DESIGN.md §2). The *identical* algorithm, constants and
RNG are implemented in Rust (``rust/src/data/synth.rs``); cross-language
equality is asserted by goldens emitted from here (tolerance 1e-4 — the
only libm calls are sin/cos/log/sqrt).

Algorithm, per sample ``index`` with dataset ``seed``:

1. RNG = SplitMix64 stream seeded with ``mix(seed, index)``.
2. label = index % 10 (balanced classes; the batcher shuffles).
3. The digit's stroke skeleton (hand-designed polylines in the unit square)
   is warped by a random affine map: rotation, anisotropic scale, shear,
   translation around the glyph centre (0.5, 0.5).
4. Each pixel's intensity is a soft distance field to the nearest stroke
   segment: v = clip((tau - d) / (0.35 * tau), 0, 1) with random stroke
   thickness tau.
5. Additive Gaussian noise (sigma = 0.04, Box-Muller), clip to [0, 1].

Images are emitted in [0, 1]; the training pipeline normalises to mean 0.5
/ std 0.5 -> [-1, 1] exactly as the paper preprocesses MNIST.
"""

from __future__ import annotations

import math
from typing import List, Tuple

import numpy as np

GRID = 28
NOISE_SIGMA = 0.04
SOFTNESS = 0.35

# ---------------------------------------------------------------------------
# SplitMix64 — bit-exact mirror of rust/src/data/rng.rs
# ---------------------------------------------------------------------------

_MASK = (1 << 64) - 1


def _splitmix64_next(state: int) -> Tuple[int, int]:
    state = (state + 0x9E3779B97F4A7C15) & _MASK
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
    z = z ^ (z >> 31)
    return state, z


class SplitMix64:
    def __init__(self, seed: int):
        self.state = seed & _MASK

    def next_u64(self) -> int:
        self.state, z = _splitmix64_next(self.state)
        return z

    def next_f64(self) -> float:
        """Uniform in [0, 1): top 53 bits."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()

    def gauss(self) -> float:
        """Box-Muller (cos branch), identical call order to Rust."""
        u1 = self.next_f64()
        u2 = self.next_f64()
        u1 = max(u1, 1e-12)
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)


def sample_seed(seed: int, index: int) -> int:
    """Per-sample stream seed: one extra SplitMix64 scramble of (seed ^ f(index))."""
    _, z = _splitmix64_next((seed ^ ((index + 1) * 0xD1B54A32D192ED03)) & _MASK)
    return z


# ---------------------------------------------------------------------------
# Stroke skeletons (polylines in the unit square, y axis pointing down)
# ---------------------------------------------------------------------------


def _circle(cx: float, cy: float, rx: float, ry: float, n: int = 12) -> List[Tuple[float, float]]:
    pts = []
    for k in range(n + 1):
        t = 2.0 * math.pi * k / n
        pts.append((cx + rx * math.cos(t), cy + ry * math.sin(t)))
    return pts


SKELETONS: dict[int, List[List[Tuple[float, float]]]] = {
    0: [_circle(0.5, 0.5, 0.24, 0.34)],
    1: [[(0.36, 0.28), (0.52, 0.14)], [(0.52, 0.14), (0.52, 0.86)]],
    2: [
        [(0.28, 0.30), (0.32, 0.17), (0.50, 0.12), (0.68, 0.18), (0.72, 0.33),
         (0.58, 0.52), (0.30, 0.84)],
        [(0.30, 0.84), (0.74, 0.84)],
    ],
    3: [
        [(0.30, 0.16), (0.55, 0.12), (0.70, 0.28), (0.52, 0.46)],
        [(0.52, 0.46), (0.72, 0.62), (0.58, 0.84), (0.30, 0.80)],
    ],
    4: [[(0.62, 0.12), (0.28, 0.62)], [(0.28, 0.62), (0.76, 0.62)], [(0.62, 0.30), (0.62, 0.88)]],
    5: [
        [(0.70, 0.13), (0.33, 0.13)],
        [(0.33, 0.13), (0.31, 0.45)],
        [(0.31, 0.45), (0.55, 0.41), (0.71, 0.56), (0.66, 0.78), (0.44, 0.87), (0.28, 0.79)],
    ],
    6: [
        [(0.64, 0.13), (0.42, 0.33), (0.32, 0.58)],
        _circle(0.48, 0.67, 0.19, 0.20),
    ],
    7: [[(0.26, 0.15), (0.74, 0.15)], [(0.74, 0.15), (0.44, 0.86)]],
    8: [_circle(0.5, 0.31, 0.17, 0.17), _circle(0.5, 0.67, 0.21, 0.20)],
    9: [
        _circle(0.5, 0.33, 0.19, 0.20),
        [(0.69, 0.37), (0.64, 0.62), (0.54, 0.86)],
    ],
}


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------


def _affine(rng: SplitMix64):
    """Random warp around the glyph centre. Draw order mirrors Rust exactly."""
    theta = rng.uniform(-0.25, 0.25)
    sx = rng.uniform(0.85, 1.15)
    sy = rng.uniform(0.85, 1.15)
    shear = rng.uniform(-0.15, 0.15)
    tx = rng.uniform(-0.08, 0.08)
    ty = rng.uniform(-0.08, 0.08)
    ct, st = math.cos(theta), math.sin(theta)
    # A = R(theta) @ Shear(shear) @ Scale(sx, sy)
    a00 = ct * sx + (-st) * 0.0
    a01 = ct * (shear * sy) - st * sy
    a10 = st * sx
    a11 = st * (shear * sy) + ct * sy
    return (a00, a01, a10, a11, tx, ty)


def _warp(pts, aff):
    a00, a01, a10, a11, tx, ty = aff
    out = []
    for (x, y) in pts:
        dx, dy = x - 0.5, y - 0.5
        out.append((0.5 + a00 * dx + a01 * dy + tx, 0.5 + a10 * dx + a11 * dy + ty))
    return out


def _seg_dist(px, py, ax, ay, bx, by) -> float:
    vx, vy = bx - ax, by - ay
    wx, wy = px - ax, py - ay
    vv = vx * vx + vy * vy
    t = 0.0 if vv <= 1e-18 else max(0.0, min(1.0, (wx * vx + wy * vy) / vv))
    dx, dy = px - (ax + t * vx), py - (ay + t * vy)
    return math.sqrt(dx * dx + dy * dy)


def render_digit(seed: int, index: int) -> Tuple[np.ndarray, int]:
    """Render sample ``index`` -> (28x28 f32 image in [0,1], label)."""
    label = index % 10
    rng = SplitMix64(sample_seed(seed, index))
    aff = _affine(rng)
    tau = rng.uniform(0.035, 0.060)
    strokes = [_warp(poly, aff) for poly in SKELETONS[label]]

    img = np.zeros((GRID, GRID), dtype=np.float64)
    for r in range(GRID):
        py = (r + 0.5) / GRID
        for c in range(GRID):
            px = (c + 0.5) / GRID
            d = math.inf
            for poly in strokes:
                for k in range(len(poly) - 1):
                    ax, ay = poly[k]
                    bx, by = poly[k + 1]
                    d = min(d, _seg_dist(px, py, ax, ay, bx, by))
            v = (tau - d) / (SOFTNESS * tau)
            img[r, c] = min(max(v, 0.0), 1.0)
    # Noise pass in the same raster order as Rust.
    for r in range(GRID):
        for c in range(GRID):
            img[r, c] = min(max(img[r, c] + NOISE_SIGMA * rng.gauss(), 0.0), 1.0)
    return img.astype(np.float32), label


def dataset(seed: int, n: int, flat: bool = False):
    """Generate n samples -> (images [n,1,28,28] or [n,784] in [-1,1], labels)."""
    xs = np.zeros((n, GRID, GRID), dtype=np.float32)
    ys = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        img, lab = render_digit(seed, i)
        xs[i] = img
        ys[i] = lab
    xs = (xs - 0.5) / 0.5  # paper's MNIST normalisation
    if flat:
        return xs.reshape(n, -1), ys
    return xs[:, None, :, :], ys
