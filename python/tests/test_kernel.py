"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Includes hypothesis sweeps over shapes, ranges and gate values: the Pallas
tiling (flatten + pad to 256x128 blocks) must be invisible for any tensor
shape, and the gated decomposition must equal a direct Eq.-1 quantization
at the bit-width selected by T(g).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant, ref

ATOL = 1e-6  # one f32 ulp of scale*n re-association
BITS = list(ref.BIT_LEVELS)


def _rand(shape, seed=0, scale=0.8):
    return np.random.default_rng(seed).normal(0.0, scale, shape).astype(np.float32)


# ---------------------------------------------------------------------------
# Fixed-bit quantizer (Eq. 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("signed", [True, False])
def test_quantize_matches_ref(bits, signed):
    x = jnp.asarray(_rand((97, 33)))
    r = ref.quantize(x, bits, 1.1, signed)
    p = fake_quant.quantize_pallas(x, 1.1, bits=bits, signed=signed)
    np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=ATOL)


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_level_count(bits):
    """A b-bit quantization admits at most 2^b distinct values."""
    x = jnp.asarray(np.linspace(-2, 2, 4001, dtype=np.float32))
    q = np.asarray(ref.quantize(x, bits, 1.0, True))
    assert len(np.unique(q)) <= 2**bits
    # signed grid is symmetric and contains exact zero
    assert 0.0 in np.unique(q)
    np.testing.assert_allclose(np.unique(q), -np.unique(q)[::-1], atol=ATOL)


def test_quantize_respects_range():
    x = jnp.asarray(_rand((512,), scale=3.0))
    for bits in BITS:
        q = np.asarray(ref.quantize(x, bits, 0.7, True))
        assert np.all(np.abs(q) <= 0.7 + ATOL)
        qu = np.asarray(ref.quantize(x, bits, 0.7, False))
        assert np.all(qu >= -ATOL) and np.all(qu <= 0.7 + ATOL)


def test_quantize_identity_at_32_bits():
    """32-bit fake quantization == clip (f32 grid denser than mantissa)."""
    x = jnp.asarray(_rand((256,)))
    q = np.asarray(ref.quantize(x, 32, 1.5, True))
    np.testing.assert_array_equal(q, np.clip(np.asarray(x), -1.5, 1.5))


def test_quantize_zero_beta_finite():
    x = jnp.asarray(_rand((64,)))
    q = np.asarray(ref.quantize(x, 4, 0.0, True))
    assert np.all(np.isfinite(q))
    np.testing.assert_allclose(q, 0.0, atol=ATOL)


# ---------------------------------------------------------------------------
# Staircase T and gate masks (Eq. 4)
# ---------------------------------------------------------------------------


def test_transform_T_staircase():
    g = jnp.asarray([-1.0, 0.0, 0.25, 0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5, 5.5])
    expect = [0, 0, 2, 2, 2, 4, 4, 8, 8, 16, 16, 32, 32]
    np.testing.assert_array_equal(np.asarray(ref.transform_T(g)), expect)


def test_gate_masks_are_nested():
    """G_2 >= G_4 >= G_8 >= G_16 >= G_32 pointwise (monotone staircase)."""
    g = jnp.asarray(np.random.default_rng(3).uniform(-1, 6, (512,)).astype(np.float32))
    masks = ref.gate_masks(g)
    for lo, hi in zip(masks[:-1], masks[1:]):
        assert np.all(np.asarray(lo) >= np.asarray(hi))


# ---------------------------------------------------------------------------
# Gated residual decomposition (Eq. 3)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("signed", [True, False])
def test_gated_matches_ref(signed):
    x = jnp.asarray(_rand((300, 77)))
    g = jnp.asarray(np.random.default_rng(1).uniform(-0.5, 5.5, (300, 77)).astype(np.float32))
    r = ref.gated_quantize(x, g, 1.2, signed)
    p = fake_quant.gated_quantize_pallas(x, g, 1.2, signed=signed)
    np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=ATOL)


@pytest.mark.parametrize("gval,bits", [(0.7, 2), (1.5, 4), (2.5, 8), (3.5, 16), (5.0, 32)])
def test_gated_equals_direct_quantization(gval, bits):
    """With a uniform gate, Eq. 3 telescopes to a direct Eq. 1 quantization."""
    x = jnp.asarray(_rand((4096,), seed=9))
    g = jnp.full_like(x, gval)
    gated = ref.gated_quantize(x, g, 1.0, True)
    direct = ref.quantize(x, bits, 1.0, True)
    np.testing.assert_allclose(np.asarray(gated), np.asarray(direct), atol=ATOL)


def test_gated_zero_gate_prunes():
    """T(g<=0) = 0 -> all masks zero -> output exactly zero (pruning limit)."""
    x = jnp.asarray(_rand((128,)))
    g = jnp.full_like(x, -0.3)
    np.testing.assert_array_equal(np.asarray(ref.gated_quantize(x, g, 1.0, True)), 0.0)


def test_gated_mixed_gates_elementwise():
    """Each element is quantized at its own T(g) — mixed precision in one tensor."""
    x = jnp.asarray(_rand((1000,), seed=5))
    g = jnp.asarray(np.random.default_rng(6).uniform(0.1, 5.5, (1000,)).astype(np.float32))
    gated = np.asarray(ref.gated_quantize(x, g, 1.0, True))
    t = np.asarray(ref.transform_T(g))
    for bits in BITS:
        m = t == bits
        if m.any():
            direct = np.asarray(ref.quantize(x, bits, 1.0, True))
            np.testing.assert_allclose(gated[m], direct[m], atol=ATOL)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: tiling must be shape/value independent
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 70000),
    beta=st.floats(0.05, 4.0),
    signed=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_gated_any_size(n, beta, signed, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1.0, (n,)).astype(np.float32))
    g = jnp.asarray(rng.uniform(-0.5, 5.5, (n,)).astype(np.float32))
    r = ref.gated_quantize(x, g, beta, signed)
    p = fake_quant.gated_quantize_pallas(x, g, beta, signed=signed)
    np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=max(ATOL, 1e-6 * beta))


@settings(max_examples=20, deadline=None)
@given(
    shape=st.tuples(st.integers(1, 40), st.integers(1, 40), st.integers(1, 12)),
    bits=st.sampled_from(BITS),
    signed=st.booleans(),
)
def test_hypothesis_quantize_nd_shapes(shape, bits, signed):
    x = jnp.asarray(np.random.default_rng(11).normal(0, 1, shape).astype(np.float32))
    r = ref.quantize(x, bits, 1.0, signed)
    p = fake_quant.quantize_pallas(x, 1.0, bits=bits, signed=signed)
    assert p.shape == x.shape
    np.testing.assert_allclose(np.asarray(r), np.asarray(p), atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(g=st.floats(-2.0, 8.0))
def test_hypothesis_T_in_levels(g):
    t = float(ref.transform_T(jnp.float32(g)))
    assert t in (0.0, 2.0, 4.0, 8.0, 16.0, 32.0)


def test_quantization_error_decreases_with_bits():
    """Residual decomposition sanity: error shrinks monotonically in b."""
    x = jnp.asarray(_rand((8192,), seed=2))
    errs = []
    for bits in BITS:
        q = ref.quantize(x, bits, 2.0, True)
        errs.append(float(jnp.mean((q - jnp.clip(x, -2, 2)) ** 2)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-10
