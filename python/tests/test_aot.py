"""AOT lowering smoke tests + BOP oracle (fast: MLP only is lowered here)."""

import json
import os

import pytest

from compile import aot
from compile.arch import ARCHS, LENET5, MLP


def test_artifact_plan_io_contract():
    """Manifest I/O ordering is the Rust runtime's ABI — pin it."""
    plans = {name: (ins, outs) for name, _, ins, outs in aot.artifact_plan(MLP)}
    ins, outs = plans["mlp_qat_step"]
    names = [n for n, _ in ins]
    assert names == [
        "fc1.w", "fc1.b", "fc2.w", "fc2.b", "fc3.w", "fc3.b",
        "betas_w", "betas_a",
        "fc1.gw", "fc2.gw", "fc3.gw", "fc1.ga", "fc2.ga",
        "x", "y",
    ]
    assert outs == [
        "loss",
        "grad.fc1.w", "grad.fc1.b", "grad.fc2.w", "grad.fc2.b",
        "grad.fc3.w", "grad.fc3.b",
        "grad.betas_w", "grad.betas_a",
        "act_grad.fc1", "act_grad.fc2",
        "act_mean.fc1", "act_mean.fc2",
    ]


def test_lower_mlp_qat_step_produces_hlo_text():
    plans = {name: (fn, ins) for name, fn, ins, _ in aot.artifact_plan(MLP)}
    fn, ins = plans["mlp_qat_step"]
    text = aot.lower_artifact(fn, ins)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # fake quantization must actually be in the graph
    assert "round-nearest" in text or "round_nearest" in text.replace("-", "_")


def test_lower_float_step_small():
    plans = {name: (fn, ins) for name, fn, ins, _ in aot.artifact_plan(MLP)}
    fn, ins = plans["mlp_float_step"]
    text = aot.lower_artifact(fn, ins)
    assert text.startswith("HloModule")


def test_bop_goldens_floor_matches_paper():
    """Paper Section 4.2: the all-2-bit RBOP floor for LeNet-5 is ~0.392%.

    Our BOP model (DESIGN.md §7: output-activation bit-widths, output layer
    excluded) gives exactly (2*2)/(32*32) = 0.390625%.
    """
    g = aot._bop_goldens()
    assert g["lenet5"]["floor_rbop_percent"] == pytest.approx(0.390625, abs=1e-9)
    assert g["mlp"]["floor_rbop_percent"] == pytest.approx(0.390625, abs=1e-9)


def test_lenet5_macs():
    macs = {l.name: l.macs for l in LENET5.layers}
    assert macs == {
        "conv1": 20 * 24 * 24 * 25,
        "conv2": 50 * 8 * 8 * 25 * 20,
        "fc1": 800 * 500,
        "fc2": 500 * 10,
    }


def test_manifest_if_built():
    """If `make artifacts` has run, the manifest must cover both archs."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    m = json.load(open(path))
    for arch in ARCHS:
        for kind in ("float_step", "qat_step", "eval", "eval_float", "calibrate"):
            name = f"{arch}_{kind}"
            assert name in m["artifacts"], name
            assert os.path.exists(
                os.path.join(os.path.dirname(path), m["artifacts"][name]["file"])
            )
    assert "archs" in m and set(m["archs"]) >= set(ARCHS)
