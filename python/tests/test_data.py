"""SynthMNIST generator properties (the MNIST substitution, DESIGN.md §2)."""

import numpy as np
import pytest

from compile import data_synth


def test_determinism():
    a, la = data_synth.render_digit(7, 3)
    b, lb = data_synth.render_digit(7, 3)
    np.testing.assert_array_equal(a, b)
    assert la == lb


def test_seed_changes_pixels_not_label():
    a, la = data_synth.render_digit(1, 3)
    b, lb = data_synth.render_digit(2, 3)
    assert la == lb == 3
    assert np.abs(a - b).max() > 0.05


def test_labels_balanced():
    _, ys = data_synth.dataset(0, 100, flat=True)
    counts = np.bincount(ys, minlength=10)
    np.testing.assert_array_equal(counts, 10)


def test_value_range_and_shape():
    xs, ys = data_synth.dataset(3, 20, flat=False)
    assert xs.shape == (20, 1, 28, 28)
    assert xs.dtype == np.float32
    assert xs.min() >= -1.0 and xs.max() <= 1.0
    xs2, _ = data_synth.dataset(3, 20, flat=True)
    assert xs2.shape == (20, 784)
    np.testing.assert_array_equal(xs2, xs.reshape(20, -1))


def test_digits_have_ink():
    """Every rendered digit has a visible stroke (not all noise)."""
    for i in range(20):
        img, _ = data_synth.render_digit(5, i)
        assert img.max() > 0.8, f"sample {i} has no stroke"
        assert 10 < (img > 0.5).sum() < 350, f"sample {i} ink mass off"


def test_classes_are_distinguishable():
    """A trivial nearest-class-mean classifier beats chance by a wide margin
    — the dataset carries class signal (it must be learnable)."""
    xs, ys = data_synth.dataset(11, 400, flat=True)
    xt, yt = data_synth.dataset(12, 200, flat=True)
    means = np.stack([xs[ys == c].mean(axis=0) for c in range(10)])
    pred = np.argmin(((xt[:, None, :] - means[None]) ** 2).sum(-1), axis=1)
    acc = (pred == yt).mean()
    assert acc > 0.6, f"nearest-mean acc {acc}"


def test_splitmix64_reference_vector():
    """Pin the RNG stream so the Rust mirror can't silently drift."""
    r = data_synth.SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    r2 = data_synth.SplitMix64(42)
    v = r2.next_f64()
    assert 0.0 <= v < 1.0
