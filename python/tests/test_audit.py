"""HLO audit invariants (L2 perf gate) — runs when artifacts are built."""

import os

import pytest

from compile import audit
from compile.arch import ARCHS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _text(name):
    path = os.path.join(ART, f"{name}.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    return open(path).read()


@pytest.mark.parametrize("arch", list(ARCHS))
def test_qat_step_round_count_is_minimal(arch):
    """Eq. 3 needs exactly 4 rounds per gated tensor + 1 for the input —
    the lowered graph must hit that minimum (no recomputation, no dropped
    FQ block)."""
    text = _text(f"{arch}_qat_step")
    got = audit.round_call_sites(text)
    assert got == audit.expected_rounds(arch, f"{arch}_qat_step")


@pytest.mark.parametrize("arch", list(ARCHS))
def test_eval_round_count_is_minimal(arch):
    text = _text(f"{arch}_eval")
    got = audit.round_call_sites(text)
    assert got == audit.expected_rounds(arch, f"{arch}_eval")


@pytest.mark.parametrize("arch", list(ARCHS))
def test_float_artifacts_have_no_quantization(arch):
    for kind in ("float_step", "eval_float", "calibrate"):
        assert audit.round_call_sites(_text(f"{arch}_{kind}")) == 0, kind


def test_transcendentals_confined_to_cross_entropy():
    counts = audit.op_counts(_text("lenet5_qat_step"))
    assert counts.get("exponential", 0) + counts.get("log", 0) <= 6
