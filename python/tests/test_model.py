"""L2 model correctness: shapes, statistics semantics, trainability.

These tests exercise exactly the functions aot.py lowers, so green here
means the artifacts encode the intended math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data_synth, model
from compile.arch import ARCHS, LENET5, MLP


def _init_params(arch, seed=0):
    rng = np.random.default_rng(seed)
    params = []
    for l in arch.layers:
        std = (2.0 / l.fan_in) ** 0.5
        params.append(jnp.asarray(rng.normal(0, std, l.w_shape).astype(np.float32)))
        params.append(jnp.zeros(l.b_shape, jnp.float32))
    return params


def _quant_state(arch, params, gate=5.5):
    betas_w = jnp.asarray(
        [float(jnp.max(jnp.abs(params[2 * i]))) for i in range(len(arch.layers))]
    )
    betas_a = jnp.asarray([3.0] * len(arch.quant_act_layers))
    gates_w = [jnp.full(l.w_shape, gate, jnp.float32) for l in arch.layers]
    gates_a = [jnp.full(l.act_shape, gate, jnp.float32) for l in arch.quant_act_layers]
    return betas_w, betas_a, gates_w, gates_a


def _batch(arch, n, seed=42):
    flat = arch.name == "mlp"
    x, y = data_synth.dataset(seed, n, flat=flat)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("arch", [MLP, LENET5], ids=lambda a: a.name)
def test_param_counts(arch):
    expected = {"mlp": 784 * 128 + 128 + 128 * 64 + 64 + 64 * 10 + 10,
                "lenet5": 431080}
    assert arch.n_params() == expected[arch.name]


@pytest.mark.parametrize("arch", [MLP, LENET5], ids=lambda a: a.name)
def test_float_forward_shapes(arch):
    params = _init_params(arch)
    x, _ = _batch(arch, 8)
    logits, acts = model.forward_float(arch, params, x)
    assert logits.shape == (8, 10)
    assert len(acts) == len(arch.quant_act_layers)
    for a, l in zip(acts, arch.quant_act_layers):
        assert a.shape == (8,) + l.act_shape


@pytest.mark.parametrize("arch", [MLP, LENET5], ids=lambda a: a.name)
def test_qat_step_output_shapes(arch):
    params = _init_params(arch)
    bw, ba, gw, ga = _quant_state(arch, params)
    x, y = _batch(arch, arch.train_batch)
    out = jax.jit(model.make_qat_step(arch))(*params, bw, ba, *gw, *ga, x, y)
    n_p = 2 * len(arch.layers)
    n_a = len(arch.quant_act_layers)
    assert len(out) == 1 + n_p + 2 + 2 * n_a
    assert out[0].shape == ()  # loss
    for i in range(n_p):  # param grads mirror param shapes
        assert out[1 + i].shape == params[i].shape
    assert out[1 + n_p].shape == (len(arch.layers),)  # grad betas_w
    assert out[2 + n_p].shape == (n_a,)  # grad betas_a
    for k, l in enumerate(arch.quant_act_layers):  # act grads + act means
        assert out[3 + n_p + k].shape == l.act_shape
        assert out[3 + n_p + n_a + k].shape == l.act_shape


def test_qat_at_32bit_gates_close_to_float():
    """With all gates at 32 bit and generous ranges, QAT logits ~ float logits."""
    arch = MLP
    params = _init_params(arch)
    x, _ = _batch(arch, 32)
    bw = jnp.asarray([float(jnp.max(jnp.abs(params[2 * i]))) * 4 for i in range(3)])
    ba = jnp.asarray([50.0, 50.0])
    gw = [jnp.full(l.w_shape, 5.5, jnp.float32) for l in arch.layers]
    ga = [jnp.full(l.act_shape, 5.5, jnp.float32) for l in arch.quant_act_layers]
    ql, _ = model.forward_quantized(arch, params, bw, ba, gw, ga, x)
    fl, _ = model.forward_float(arch, params, x)
    # only the fixed 8-bit input quantization separates them
    assert float(jnp.max(jnp.abs(ql - fl))) < 0.15


def test_lower_bits_increase_distortion():
    arch = MLP
    params = _init_params(arch)
    x, _ = _batch(arch, 32)
    bw, ba, _, _ = _quant_state(arch, params)
    fl, _ = model.forward_float(arch, params, x)
    dist = []
    for gate in (5.5, 2.5, 0.7):  # 32 -> 8 -> 2 bits
        gw = [jnp.full(l.w_shape, gate, jnp.float32) for l in arch.layers]
        ga = [jnp.full(l.act_shape, gate, jnp.float32) for l in arch.quant_act_layers]
        ql, _ = model.forward_quantized(arch, params, bw, ba, gw, ga, x)
        dist.append(float(jnp.mean((ql - fl) ** 2)))
    assert dist[0] < dist[1] < dist[2]


def test_act_mean_statistic_semantics():
    """act_mean output == batch mean of the quantized activation tensor."""
    arch = MLP
    params = _init_params(arch)
    bw, ba, gw, ga = _quant_state(arch, params)
    x, y = _batch(arch, arch.train_batch)
    out = jax.jit(model.make_qat_step(arch))(*params, bw, ba, *gw, *ga, x, y)
    act_mean_fc1 = out[-2]
    # recompute directly from the forward pass
    _, act_means = model.forward_quantized(arch, params, bw, ba, gw, ga, x)
    np.testing.assert_allclose(np.asarray(act_mean_fc1), np.asarray(act_means[0]), atol=1e-5)
    assert float(jnp.max(act_means[0])) > 0  # ReLU output, some units active


def test_act_grad_statistic_is_probe_gradient():
    """act_grad == d(mean loss)/d(activation), batch-summed via broadcast probe."""
    arch = MLP
    params = _init_params(arch)
    bw, ba, gw, ga = _quant_state(arch, params)
    x, y = _batch(arch, arch.train_batch)
    out = jax.jit(model.make_qat_step(arch))(*params, bw, ba, *gw, *ga, x, y)
    n_p = 2 * len(arch.layers)
    act_grad_fc1 = np.asarray(out[3 + n_p])
    assert act_grad_fc1.shape == (128,)
    assert np.isfinite(act_grad_fc1).all()
    assert np.abs(act_grad_fc1).max() > 0


def test_float_step_trains():
    """A few float steps reduce the loss (sanity of loss/grads)."""
    arch = MLP
    params = _init_params(arch)
    x, y = _batch(arch, arch.train_batch)
    step = jax.jit(model.make_float_step(arch))
    losses = []
    for _ in range(15):
        out = step(*params, x, y)
        losses.append(float(out[0]))
        grads = out[1:]
        params = [p - 0.05 * g for p, g in zip(params, grads)]
    assert losses[-1] < losses[0] * 0.7


def test_qat_step_trains_at_8bit():
    """QAT fwd/bwd with 8-bit gates still learns (STE works through Eq. 3)."""
    arch = MLP
    params = _init_params(arch)
    bw, ba, gw, ga = _quant_state(arch, params, gate=2.5)  # 8 bit everywhere
    x, y = _batch(arch, arch.train_batch)
    step = jax.jit(model.make_qat_step(arch))
    losses = []
    for _ in range(15):
        out = step(*params, bw, ba, *gw, *ga, x, y)
        losses.append(float(out[0]))
        grads = out[1 : 1 + 6]
        params = [p - 0.05 * g for p, g in zip(params, grads)]
    assert losses[-1] < losses[0] * 0.8


def test_calibrate_outputs():
    arch = MLP
    params = _init_params(arch)
    x, _ = _batch(arch, arch.train_batch)
    w_maxes, act_maxes, logit_mean = jax.jit(model.make_calibrate(arch))(*params, x)
    assert w_maxes.shape == (3,)
    assert act_maxes.shape == (2,)
    for i in range(3):
        assert float(w_maxes[i]) == pytest.approx(
            float(jnp.max(jnp.abs(params[2 * i]))), rel=1e-6
        )
    assert np.all(np.asarray(act_maxes) > 0)
    assert np.isfinite(float(logit_mean))


def test_cross_entropy_reference():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]], jnp.float32)
    y = jnp.asarray([0, 1], jnp.int32)
    got = float(model._cross_entropy(logits, y))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    p1 = np.exp(3.0) / (np.exp(3.0) + 2)
    expect = -(np.log(p0) + np.log(p1)) / 2
    assert got == pytest.approx(expect, rel=1e-5)
