"""Gradient rules of the STE quantizer wrappers (L2 <- L1 boundary).

The paper's training relies on three gradient conventions:
  1. STE for values (identity inside [alpha, beta], zero outside),
  2. an LSQ-style range gradient for the learnable beta,
  3. *exactly zero* gradient for the gates (dir replaces it — Section 2.2).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.quantizer import gated_quantize_ste, quantize_ste
from compile.kernels import ref


def test_ste_value_gradient_inside_range():
    x = jnp.asarray([-0.9, -0.3, 0.0, 0.4, 0.8], jnp.float32)
    g = jax.grad(lambda x: jnp.sum(quantize_ste(x, jnp.float32(1.0), 4, True)))(x)
    np.testing.assert_array_equal(np.asarray(g), 1.0)


def test_ste_value_gradient_clipped_is_zero():
    x = jnp.asarray([-3.0, -1.5, 1.5, 3.0], jnp.float32)
    g = jax.grad(lambda x: jnp.sum(quantize_ste(x, jnp.float32(1.0), 4, True)))(x)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_range_gradient_sign_on_tails():
    """d q / d beta = +1 above beta, -1 below -beta (signed)."""
    beta = jnp.float32(1.0)
    for xv, expect in [(2.0, 1.0), (-2.0, -1.0)]:
        gb = jax.grad(
            lambda b: jnp.sum(quantize_ste(jnp.asarray([xv], jnp.float32), b, 4, True)),
        )(beta)
        assert float(gb) == pytest.approx(expect)


def test_range_gradient_unsigned_no_negative_tail():
    beta = jnp.float32(1.0)
    gb = jax.grad(
        lambda b: jnp.sum(quantize_ste(jnp.asarray([-2.0], jnp.float32), b, 4, False)),
    )(beta)
    assert float(gb) == 0.0


def test_range_gradient_interior_is_scale_error():
    """Interior elements contribute (q - v)/beta."""
    beta = jnp.float32(1.0)
    x = jnp.asarray([0.37], jnp.float32)
    q = float(ref.quantize(x, 2, 1.0, True)[0])
    gb = jax.grad(lambda b: jnp.sum(quantize_ste(x, b, 2, True)))(beta)
    assert float(gb) == pytest.approx(q - 0.37, abs=1e-6)


def test_gate_gradient_is_exactly_zero():
    """The paper's core premise: loss gradient w.r.t. gates is zero."""
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)).astype(np.float32))
    g = jnp.asarray(np.random.default_rng(1).uniform(0.5, 5.5, (64,)).astype(np.float32))

    def loss(g):
        return jnp.sum(gated_quantize_ste(x, g, jnp.float32(1.0), True) ** 2)

    grad_g = jax.grad(loss)(g)
    np.testing.assert_array_equal(np.asarray(grad_g), 0.0)


def test_gated_ste_value_gradient_masks_clip():
    x = jnp.asarray([-2.0, -0.5, 0.5, 2.0], jnp.float32)
    g = jnp.full_like(x, 2.5)  # 8-bit

    def s(x):
        return jnp.sum(gated_quantize_ste(x, g, jnp.float32(1.0), True))

    gx = np.asarray(jax.grad(s)(x))
    np.testing.assert_array_equal(gx, [0.0, 1.0, 1.0, 0.0])


def test_gated_primal_matches_ref():
    x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (513,)).astype(np.float32))
    g = jnp.asarray(np.random.default_rng(3).uniform(-0.5, 5.5, (513,)).astype(np.float32))
    p = gated_quantize_ste(x, g, jnp.float32(1.3), True)
    r = ref.gated_quantize(x, g, 1.3, True)
    np.testing.assert_allclose(np.asarray(p), np.asarray(r), atol=1e-6)


def test_range_gradient_flows_through_gated():
    """beta receives a finite, generally nonzero gradient through Eq. 3."""
    x = jnp.asarray(np.random.default_rng(4).normal(0, 2, (256,)).astype(np.float32))
    g = jnp.full_like(x, 1.5)  # 4-bit
    gb = jax.grad(lambda b: jnp.sum(gated_quantize_ste(x, g, b, True)))(jnp.float32(1.0))
    assert np.isfinite(float(gb))
    assert abs(float(gb)) > 0.0
