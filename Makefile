# Developer entry points. `make ci` is the tier-1 gate CI runs.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: ci build test fmt fmt-fix clippy bench-smoke serve-smoke route-smoke artifacts bench clean

ci: build test fmt clippy bench-smoke serve-smoke route-smoke

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Compile + execute the deploy engine hot path (tiny iteration counts and
# the cross-path golden assertion) on every PR.
bench-smoke:
	$(CARGO) bench --bench bench_deploy -- --smoke

# End-to-end serve smoke: export a packed model, run it on synthetic
# inputs, then drive the pooled serve bench (1 vs 4 workers). A *trained*
# export needs a pjrt build + `make artifacts`; `export --synth` packs the
# deterministic synthetic mixed-precision state instead, exercising the
# identical pack -> save -> load -> infer -> pooled-serve path offline.
serve-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --out runs/serve-smoke.cgmqm
	./target/release/cgmq infer --model runs/serve-smoke.cgmqm --synth 8
	./target/release/cgmq serve-bench --model runs/serve-smoke.cgmqm \
		--requests 96 --batch 16 --workers 4

# Multi-model routing smoke: export two synthetic budget variants, then
# drive the router bench with a tiny per-shard queue cap so the shed
# (429) path actually executes, plus a mid-traffic hot swap of every
# model (--swap). The bench itself asserts the per-model accounting
# invariant (submitted == accepted + shed, nothing lost).
route-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --seed 7 --out runs/route-a.cgmqm
	./target/release/cgmq export --synth --arch mlp --seed 8 --out runs/route-b.cgmqm
	./target/release/cgmq route-bench --models a=runs/route-a.cgmqm,b=runs/route-b.cgmqm \
		--requests 96 --batch 8 --workers 2 --queue-cap 2 --swap

fmt-fix:
	$(CARGO) fmt

# AOT-compile the JAX/Pallas models to HLO-text artifacts + manifest.json
# (needed by training runs and the artifact-gated integration tests).
artifacts:
	$(PYTHON) python/compile/aot.py

bench:
	$(CARGO) bench --bench bench_hot_paths
	$(CARGO) bench --bench bench_tables

clean:
	$(CARGO) clean
