# Developer entry points. `make ci` is the tier-1 gate CI runs.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: ci build test fmt fmt-fix artifacts bench clean

ci: build test fmt

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

fmt-fix:
	$(CARGO) fmt

# AOT-compile the JAX/Pallas models to HLO-text artifacts + manifest.json
# (needed by training runs and the artifact-gated integration tests).
artifacts:
	$(PYTHON) python/compile/aot.py

bench:
	$(CARGO) bench --bench bench_hot_paths
	$(CARGO) bench --bench bench_tables

clean:
	$(CARGO) clean
