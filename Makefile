# Developer entry points. `make ci` is the tier-1 gate CI runs.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: ci build test fmt fmt-fix clippy analyze kernel-smoke bench-smoke serve-smoke route-smoke net-smoke metrics-smoke watch-smoke artifacts bench clean

ci: build test fmt clippy analyze kernel-smoke serve-smoke route-smoke net-smoke metrics-smoke watch-smoke

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

# `-D warnings` plus the std-only lints closest to the analyzer's remit
# (await_holding_lock is async-only, so the sync analogue lives in the
# analyzer's lock-scope rule): dbg!/todo!/unimplemented! left in tree.
clippy:
	$(CARGO) clippy --all-targets -- -D warnings \
		-D clippy::dbg_macro -D clippy::todo -D clippy::unimplemented

# The repo's own invariant lint pass (see README "Static analysis"):
# panic hygiene in deploy/ hot paths, atomic-ordering justifications,
# SeqCst on hot paths, lock scopes, counter choke points, README status
# taxonomy + metric-name sync. Exits non-zero on any finding.
analyze: build
	./target/release/cgmq analyze --root .

# Compile + execute the deploy engine hot path (tiny iteration counts)
# on every PR: the blocked-GEMM == naive-oracle bit-equality, both
# cross-path goldens (mlp dense AND the lenet5 im2col+GEMM conv path),
# the per-op compute split rows, and the SWAR width sweep — synthetic
# uniform 2/4/8-bit exports on both archs, plan-introspected (every op
# must select its Swar{2,4,8} kernel; the forced baseline must stay
# F32Gemm) and golden-anchored bit-for-bit against the fake-quant
# reference. Speedups are printed in smoke; the 1.5x floor on uniform
# 4-bit mlp is asserted by the full `make bench` run.
kernel-smoke:
	$(CARGO) bench --bench bench_deploy -- --smoke

# Back-compat alias for the pre-kernel-layer target name.
bench-smoke: kernel-smoke

# End-to-end serve smoke: export a packed model, run it on synthetic
# inputs, then drive the pooled serve bench (1 vs 4 workers). A *trained*
# export needs a pjrt build + `make artifacts`; `export --synth` packs the
# deterministic synthetic mixed-precision state instead, exercising the
# identical pack -> save -> load -> infer -> pooled-serve path offline.
serve-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --out runs/serve-smoke.cgmqm
	./target/release/cgmq infer --model runs/serve-smoke.cgmqm --synth 8
	./target/release/cgmq serve-bench --model runs/serve-smoke.cgmqm \
		--requests 96 --batch 16 --workers 4

# Multi-model routing smoke: export two synthetic budget variants, then
# drive the router bench with a tiny per-shard queue cap so the shed
# (429) path actually executes, plus a mid-traffic hot swap of every
# model (--swap). The bench itself asserts the per-model accounting
# invariant (submitted == accepted + shed, nothing lost).
route-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --seed 7 --out runs/route-a.cgmqm
	./target/release/cgmq export --synth --arch mlp --seed 8 --out runs/route-b.cgmqm
	./target/release/cgmq route-bench --models a=runs/route-a.cgmqm,b=runs/route-b.cgmqm \
		--requests 96 --batch 8 --workers 2 --queue-cap 2 --swap

# End-to-end network serving smoke: export a synthetic model, run it once
# through direct `cgmq infer` (the in-process reference path), start
# `cgmq serve` on an ephemeral loopback port (workers=1, queue-cap=1 and a
# 5ms batching deadline, so a 4-client burst saturates admission and MUST
# observe >= 1 shed mapped to 429), then drive it with `cgmq load-bench`:
# every HTTP response is asserted bit-identical to the locally loaded
# engine (--verify-model), --min-shed 1 asserts the 429 path executed, and
# --shutdown drains the server via /admin/shutdown — `wait` propagates the
# server's exit code, which is non-zero if any accepted request was lost.
net-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --out runs/net-smoke.cgmqm
	./target/release/cgmq infer --model runs/net-smoke.cgmqm --synth 8
	rm -f runs/net-smoke.addr; \
	./target/release/cgmq serve --models m=runs/net-smoke.cgmqm --addr 127.0.0.1:0 \
		--workers 1 --queue-cap 1 --batch 64 --deadline-us 5000 \
		--addr-file runs/net-smoke.addr & \
	pid=$$!; \
	i=0; while [ ! -s runs/net-smoke.addr ] && [ $$i -lt 100 ]; do sleep 0.1; i=$$((i+1)); done; \
	if [ ! -s runs/net-smoke.addr ]; then echo "cgmq serve did not come up"; kill $$pid 2>/dev/null; exit 1; fi; \
	if ! ./target/release/cgmq load-bench --addr $$(cat runs/net-smoke.addr) --key m \
		--requests 96 --clients 4 --verify-model runs/net-smoke.cgmqm \
		--min-shed 1 --shutdown; then \
		kill $$pid 2>/dev/null; wait $$pid; exit 1; \
	fi; \
	wait $$pid

# Telemetry smoke: same loopback shape as net-smoke, but the point is the
# observability spine — after the saturating burst `cgmq load-bench`
# scrapes GET /metrics and exits non-zero unless the server-side
# accepted/shed counters match its own client-observed totals bit-exactly
# and (--require-stages) every pipeline stage histogram recorded samples.
metrics-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --out runs/metrics-smoke.cgmqm
	rm -f runs/metrics-smoke.addr; \
	./target/release/cgmq serve --models m=runs/metrics-smoke.cgmqm --addr 127.0.0.1:0 \
		--workers 1 --queue-cap 1 --batch 64 --deadline-us 5000 \
		--addr-file runs/metrics-smoke.addr & \
	pid=$$!; \
	i=0; while [ ! -s runs/metrics-smoke.addr ] && [ $$i -lt 100 ]; do sleep 0.1; i=$$((i+1)); done; \
	if [ ! -s runs/metrics-smoke.addr ]; then echo "cgmq serve did not come up"; kill $$pid 2>/dev/null; exit 1; fi; \
	if ! ./target/release/cgmq load-bench --addr $$(cat runs/metrics-smoke.addr) --key m \
		--requests 96 --clients 4 --min-shed 1 --require-stages --shutdown; then \
		kill $$pid 2>/dev/null; wait $$pid; exit 1; \
	fi; \
	wait $$pid

# Windowed-signal-plane smoke: same loopback shape, but the point is the
# live plane — a saturating burst puts traffic in the trailing window,
# `cgmq watch --once` renders a frame off GET /stats (proving the watch
# path parses a real server), and a second `cgmq load-bench` pass with
# --require-window asserts the plane is live: positive windowed arrival
# rate, recorded margin samples, and GET /livez answering 200 — then
# drains the server via --shutdown.
watch-smoke: build
	mkdir -p runs
	./target/release/cgmq export --synth --arch mlp --out runs/watch-smoke.cgmqm
	rm -f runs/watch-smoke.addr; \
	./target/release/cgmq serve --models m=runs/watch-smoke.cgmqm --addr 127.0.0.1:0 \
		--workers 1 --queue-cap 1 --batch 64 --deadline-us 5000 \
		--addr-file runs/watch-smoke.addr & \
	pid=$$!; \
	i=0; while [ ! -s runs/watch-smoke.addr ] && [ $$i -lt 100 ]; do sleep 0.1; i=$$((i+1)); done; \
	if [ ! -s runs/watch-smoke.addr ]; then echo "cgmq serve did not come up"; kill $$pid 2>/dev/null; exit 1; fi; \
	if ! ./target/release/cgmq load-bench --addr $$(cat runs/watch-smoke.addr) --key m \
		--requests 96 --clients 4 --min-shed 1; then \
		kill $$pid 2>/dev/null; wait $$pid; exit 1; \
	fi; \
	if ! ./target/release/cgmq watch --addr $$(cat runs/watch-smoke.addr) --once; then \
		kill $$pid 2>/dev/null; wait $$pid; exit 1; \
	fi; \
	if ! ./target/release/cgmq load-bench --addr $$(cat runs/watch-smoke.addr) --key m \
		--requests 32 --clients 2 --require-window --shutdown; then \
		kill $$pid 2>/dev/null; wait $$pid; exit 1; \
	fi; \
	wait $$pid

fmt-fix:
	$(CARGO) fmt

# AOT-compile the JAX/Pallas models to HLO-text artifacts + manifest.json
# (needed by training runs and the artifact-gated integration tests).
artifacts:
	$(PYTHON) python/compile/aot.py

bench:
	$(CARGO) bench --bench bench_hot_paths
	$(CARGO) bench --bench bench_tables
	$(CARGO) bench --bench bench_deploy

clean:
	$(CARGO) clean
