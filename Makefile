# Developer entry points. `make ci` is the tier-1 gate CI runs.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: ci build test fmt fmt-fix clippy bench-smoke artifacts bench clean

ci: build test fmt clippy bench-smoke

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Compile + execute the deploy engine hot path (tiny iteration counts and
# the cross-path golden assertion) on every PR.
bench-smoke:
	$(CARGO) bench --bench bench_deploy -- --smoke

fmt-fix:
	$(CARGO) fmt

# AOT-compile the JAX/Pallas models to HLO-text artifacts + manifest.json
# (needed by training runs and the artifact-gated integration tests).
artifacts:
	$(PYTHON) python/compile/aot.py

bench:
	$(CARGO) bench --bench bench_hot_paths
	$(CARGO) bench --bench bench_tables

clean:
	$(CARGO) clean
