#!/usr/bin/env python3
"""Numeric cross-check of the SWAR kernel algebra in deploy/kernels/swar.rs.

The container this repo grows in has no Rust toolchain, so this script
simulates the three load-bearing numeric claims the kernel makes and
fails loudly if any of them is wrong:

1. **Offset algebra + lane discipline.** For random signed weight codes
   and signed/unsigned activation codes, packing both sides as
   offset-encoded unsigned values, accumulating whole-u64-word
   multiply-adds with the flush cadence `floor(lane_cap / (s_max*l_max))`,
   and correcting with `dot = S - l_off*rowsum - s_off*colsum +
   k*s_off*l_off` reproduces the plain integer dot product exactly —
   including that no lane ever exceeds its width between flushes (the
   cross-lane-carry bound) and no i32 accumulator overflows at the
   plan-checked `k * s_max * l_max <= i32::MAX` bound.

2. **Exact code recovery.** For every grid the engine can meet
   (widths 2..8, signed and unsigned, a spread of betas), the fake-quant
   store `v = f32(scale) * n` is inverted exactly by
   `round_ties_even(v * f32(1/scale))` in f32 arithmetic — the
   engine-side and reference-side code recovery agree with the true
   integer code for every representable grid point.

3. **Rescale equivalence.** `f32(i64 dot) * f32(combined_scale)` is the
   same operation on both engine and reference sides by construction;
   simulated here only to confirm `i64 -> f32` conversion of in-bound
   dots is exact-roundable the same way from the offset-assembled and
   naive sums (they are equal integers, so trivially yes).

Run: python3 tools/swar_sim.py
"""

import random
import struct
import sys


def f32(x: float) -> float:
    return struct.unpack("f", struct.pack("f", x))[0]


def round_ties_even(x: float) -> int:
    # Python's round() is round-half-to-even, matching Rust round_ties_even.
    return round(x)


def step_size(bits: int, beta: float, signed: bool) -> float:
    alpha = -beta if signed else 0.0
    levels = (1 << bits) - 1
    return f32(max(f32(f32(beta - alpha) / levels), 1e-12))


def check_offset_algebra(trials: int = 300) -> None:
    rng = random.Random(0x5117)
    for t in range(trials):
        w_bits = rng.choice([2, 4, 8])
        a_bits, a_signed = rng.choice([(2, False), (4, False), (8, False), (8, True)])
        w_off = (1 << (w_bits - 1)) - 1
        w_max = (1 << w_bits) - 2
        if a_signed:
            a_off = (1 << (a_bits - 1)) - 1
            a_max = 2 * a_off
        else:
            a_off, a_max = 0, (1 << a_bits) - 1
        prod = w_max * a_max
        lane_bits = 16 if (0xFFFF // prod) >= 8 else 32
        cap = (1 << lane_bits) - 1
        flush = cap // prod
        lpw = 64 // lane_bits
        k = rng.choice([1, 3, 17, 63, 64, 65, 129, 200])
        assert k * prod <= 2**31 - 1, "test shapes stay inside the plan bound"
        m, n = 2, 7
        qa_hi = (1 << (a_bits - 1)) - 1 if a_signed else (1 << a_bits) - 1
        qa_lo = -qa_hi if a_signed else 0
        qw_hi = (1 << (w_bits - 1)) - 1
        qa = [[rng.randint(qa_lo, qa_hi) for _ in range(k)] for _ in range(m)]
        qw = [[rng.randint(-qw_hi, qw_hi) for _ in range(k)] for _ in range(n)]
        # Pack lane side (weights, offset) into u64 words, stripe-major.
        nb = -(-n // lpw)
        words = [[0] * k for _ in range(nb)]
        colsum = [0] * n
        for j in range(n):
            for i in range(k):
                u = qw[j][i] + w_off
                assert 0 <= u <= w_max
                words[j // lpw][i] |= u << ((j % lpw) * lane_bits)
                colsum[j] += u
        rowsum = [sum(q + a_off for q in row) for row in qa]
        base = k * a_off * w_off
        for r in range(m):
            for jb in range(nb):
                acc = [0] * lpw  # the i32 accumulators
                i = 0
                while i < k:
                    end = min(i + max(flush, 1), k)
                    word = 0
                    for p in range(i, end):
                        s = qa[r][p] + a_off
                        word = (word + words[jb][p] * s) & ((1 << 64) - 1)
                    # lane extraction must see no cross-lane carry:
                    for l in range(lpw):
                        lane = (word >> (l * lane_bits)) & ((1 << lane_bits) - 1)
                        assert lane <= (end - i) * prod <= cap, "lane overflow"
                        acc[l] += lane
                    i = end
                for l in range(lpw):
                    j = jb * lpw + l
                    if j >= n:
                        continue
                    assert acc[l] <= 2**31 - 1, "i32 accumulator overflow"
                    dot = acc[l] - w_off * rowsum[r] - a_off * colsum[j] + base
                    want = sum(qa[r][i] * qw[j][i] for i in range(k))
                    assert dot == want, (t, r, j, dot, want)
    print(f"offset algebra: {trials} random shapes exact (widths 2/4/8, lanes 16/32)")


def check_code_recovery() -> None:
    cases = 0
    for bits in range(2, 9):
        for signed in (True, False):
            for beta in (1.0, 1.5, 3.0, 6.0, 0.37, 123.456):
                s = step_size(bits, beta, signed)
                inv = f32(1.0 / s)
                hi = (1 << (bits - 1)) - 1 if signed else (1 << bits) - 1
                lo = -hi if signed else 0
                for q in range(lo, hi + 1):
                    v = f32(s * q)  # the fake-quant store
                    got = round_ties_even(f32(v * inv))
                    assert got == q, (bits, signed, beta, q, got)
                    cases += 1
    print(f"code recovery: {cases} grid points inverted exactly")


def main() -> int:
    check_offset_algebra()
    check_code_recovery()
    print("swar_sim: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
